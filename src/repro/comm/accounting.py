"""Logical communication accounting — the paper's reported metric
("floating-point parameters shared per worker", Figs. 5-8) plus the
real-byte wire ledger added with the codec subsystem.

Two parallel books are kept per round:

* ``uplink_floats`` / ``vanilla_floats`` — the paper's idealized
  fp32-scalar count (a top-k value is 1.5 floats, a scalar round is 1
  float), unchanged since PR 1 so historical trajectories stay
  comparable.
* ``wire_bytes`` / ``vanilla_wire_bytes`` — bytes a NIC would actually
  move under the active :mod:`repro.comm.wire` codec (quantized values,
  varint-delta index streams, per-row scales, 1-byte rho scalars).
  ``vanilla_wire_bytes`` prices the same participants shipping the dense
  model in fp32 (4 bytes/parameter), so ``wire_savings`` reports the
  end-to-end reduction of sparsification *and* quantization together.

The physical ICI collective of the mesh simulation is analyzed separately
by ``repro.analysis.roofline``; this module tracks the FL uplink a real
client<->server deployment would pay.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class CommLedger:
    rounds: int = 0
    uplink_floats: float = 0.0
    vanilla_floats: float = 0.0
    wire_bytes: float = 0.0
    vanilla_wire_bytes: float = 0.0
    #: cumulative wire bytes per aggregation tier when the engine runs a
    #: hierarchical tier map (``FLConfig.tiers``): ``"edge"`` carries the
    #: clients' sparse payloads, ``"region"``/``"global"`` carry dense
    #: partial-carry models between aggregation levels
    tier_wire_bytes: Dict[str, float] = field(default_factory=dict)
    #: buffered-scheduler payloads dropped by max-staleness eviction
    #: (``latency_kw={"max_staleness": s}``)
    n_evicted: float = 0.0
    per_round: List[Dict[str, float]] = field(default_factory=list)

    def record(self, uplink: float, vanilla: float,
               wire: float = 0.0, vanilla_wire: float = 0.0,
               tiers: Optional[Dict[str, float]] = None):
        self.rounds += 1
        self.uplink_floats += uplink
        self.vanilla_floats += vanilla
        self.wire_bytes += wire
        self.vanilla_wire_bytes += vanilla_wire
        entry = {"uplink": uplink, "vanilla": vanilla,
                 "wire": wire, "vanilla_wire": vanilla_wire}
        if tiers is not None:
            for name, b in tiers.items():
                self.tier_wire_bytes[name] = (
                    self.tier_wire_bytes.get(name, 0.0) + float(b))
            entry["tiers"] = {k: float(v) for k, v in tiers.items()}
        self.per_round.append(entry)

    def state_dict(self) -> dict:
        """Checkpointable snapshot (plain dict of floats/lists — survives
        a ``repro.checkpoint.ckpt`` flatten/unflatten round-trip)."""
        return {"rounds": float(self.rounds),
                "uplink_floats": self.uplink_floats,
                "vanilla_floats": self.vanilla_floats,
                "wire_bytes": self.wire_bytes,
                "vanilla_wire_bytes": self.vanilla_wire_bytes,
                "tier_wire_bytes": dict(self.tier_wire_bytes),
                "n_evicted": self.n_evicted,
                "per_round": list(self.per_round)}

    def load_state(self, state: dict) -> None:
        self.rounds = int(state["rounds"])
        self.uplink_floats = float(state["uplink_floats"])
        self.vanilla_floats = float(state["vanilla_floats"])
        self.wire_bytes = float(state["wire_bytes"])
        self.vanilla_wire_bytes = float(state["vanilla_wire_bytes"])
        self.tier_wire_bytes = {
            k: float(v) for k, v in state.get("tier_wire_bytes", {}).items()}
        self.n_evicted = float(state.get("n_evicted", 0.0))
        self.per_round = [
            {k: ({kk: float(vv) for kk, vv in v.items()}
                 if isinstance(v, dict) else float(v))
             for k, v in entry.items()}
            for entry in state.get("per_round", [])]

    @property
    def savings(self) -> float:
        if self.vanilla_floats == 0:
            return 0.0
        return 1.0 - self.uplink_floats / self.vanilla_floats

    @property
    def wire_savings(self) -> float:
        if self.vanilla_wire_bytes == 0:
            return 0.0
        return 1.0 - self.wire_bytes / self.vanilla_wire_bytes

    def summary(self) -> Dict[str, float]:
        out = {"rounds": self.rounds, "uplink_floats": self.uplink_floats,
               "vanilla_floats": self.vanilla_floats,
               "savings": self.savings,
               "wire_bytes": self.wire_bytes,
               "vanilla_wire_bytes": self.vanilla_wire_bytes,
               "wire_savings": self.wire_savings}
        if self.tier_wire_bytes:
            out["tier_wire_bytes"] = dict(self.tier_wire_bytes)
        if self.n_evicted:
            out["n_evicted"] = self.n_evicted
        return out
