"""Logical communication accounting — the paper's reported metric
("floating-point parameters shared per worker", Figs. 5-8).

The physical ICI collective of the mesh simulation is analyzed separately by
``repro.analysis.roofline``; this module tracks the FL uplink a real
client<->server deployment would pay.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class CommLedger:
    rounds: int = 0
    uplink_floats: float = 0.0
    vanilla_floats: float = 0.0
    per_round: List[Dict[str, float]] = field(default_factory=list)

    def record(self, uplink: float, vanilla: float):
        self.rounds += 1
        self.uplink_floats += uplink
        self.vanilla_floats += vanilla
        self.per_round.append({"uplink": uplink, "vanilla": vanilla})

    @property
    def savings(self) -> float:
        if self.vanilla_floats == 0:
            return 0.0
        return 1.0 - self.uplink_floats / self.vanilla_floats

    def summary(self) -> Dict[str, float]:
        return {"rounds": self.rounds, "uplink_floats": self.uplink_floats,
                "vanilla_floats": self.vanilla_floats,
                "savings": self.savings}
