"""Logical communication accounting — the paper's reported metric
("floating-point parameters shared per worker", Figs. 5-8) plus the
real-byte wire ledger added with the codec subsystem.

Two parallel books are kept per round:

* ``uplink_floats`` / ``vanilla_floats`` — the paper's idealized
  fp32-scalar count (a top-k value is 1.5 floats, a scalar round is 1
  float), unchanged since PR 1 so historical trajectories stay
  comparable.
* ``wire_bytes`` / ``vanilla_wire_bytes`` — bytes a NIC would actually
  move under the active :mod:`repro.comm.wire` codec (quantized values,
  varint-delta index streams, per-row scales, 1-byte rho scalars).
  ``vanilla_wire_bytes`` prices the same participants shipping the dense
  model in fp32 (4 bytes/parameter), so ``wire_savings`` reports the
  end-to-end reduction of sparsification *and* quantization together.

The physical ICI collective of the mesh simulation is analyzed separately
by ``repro.analysis.roofline``; this module tracks the FL uplink a real
client<->server deployment would pay.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class CommLedger:
    rounds: int = 0
    uplink_floats: float = 0.0
    vanilla_floats: float = 0.0
    wire_bytes: float = 0.0
    vanilla_wire_bytes: float = 0.0
    per_round: List[Dict[str, float]] = field(default_factory=list)

    def record(self, uplink: float, vanilla: float,
               wire: float = 0.0, vanilla_wire: float = 0.0):
        self.rounds += 1
        self.uplink_floats += uplink
        self.vanilla_floats += vanilla
        self.wire_bytes += wire
        self.vanilla_wire_bytes += vanilla_wire
        self.per_round.append({"uplink": uplink, "vanilla": vanilla,
                               "wire": wire, "vanilla_wire": vanilla_wire})

    @property
    def savings(self) -> float:
        if self.vanilla_floats == 0:
            return 0.0
        return 1.0 - self.uplink_floats / self.vanilla_floats

    @property
    def wire_savings(self) -> float:
        if self.vanilla_wire_bytes == 0:
            return 0.0
        return 1.0 - self.wire_bytes / self.vanilla_wire_bytes

    def summary(self) -> Dict[str, float]:
        return {"rounds": self.rounds, "uplink_floats": self.uplink_floats,
                "vanilla_floats": self.vanilla_floats,
                "savings": self.savings,
                "wire_bytes": self.wire_bytes,
                "vanilla_wire_bytes": self.vanilla_wire_bytes,
                "wire_savings": self.wire_savings}
