"""Uplink wire codecs — quantized payload encoding + real-byte accounting.

The compressor pipeline (``repro.compression``) and the LBGM store decide
*what* a client uploads (dense update, sparse top-k ``(idx, val)`` payload,
or a single scalar rho); this module decides *how those numbers sit on the
wire* and prices the bytes a NIC would actually move. Codecs resolve
through ``repro.fed.registry.CODECS`` (``FLConfig.codec`` /
``FLConfig.codec_kw``, validated at construction, JSON/CLI round-trip like
every other knob):

``none``
    fp32 legacy wire format — payload values and round history are
    bit-for-bit the pre-codec engine; only the new ``wire_bytes`` metric
    is added (computed from static sizes + the existing ``sent_scalar``
    flag, so it reads no payload data).
``delta_idx``
    lossless index compression for the sparse payloads: values stay
    fp32, the index stream is delta-coded (below). Bit-for-bit values.
``int8`` / ``fp8``
    stochastically rounded value quantization (int8 grid, or fp8 e4m3)
    with one fp32 scale per block row (sparse payloads) or per leaf
    (dense payloads), plus delta-coded indices and a 1-byte e4m3 rho on
    scalar rounds. ``codec_kw={"stochastic": false}`` switches to
    deterministic round-to-nearest.

Wire format (one full-round sparse payload, per leaf; block layout from
``repro.core.lbgm._block_layout`` — ``nb`` rows of ``kb`` entries)::

    [values]   nb*kb * value_bytes      (4 = fp32 | 1 = int8/fp8 e4m3)
    [scales]   nb * 4                   (quantized codecs only; fp32,
                                         power-of-two, one per block row)
    [indices]  raw: nb*kb * 4 (int32)
               delta-coded: per row, indices sorted ascending, first
               index then successive deltas, each as a varint:
               1 byte (< 2^7) / 2 bytes (< 2^14) / 3 bytes otherwise
    scalar (recycle) round: scalar_bytes total (4 = fp32 rho | 1 = e4m3)
    dense full round: M * value_bytes + 4 per leaf scale (quantized only)

Quantization uses power-of-two scales (``2^ceil(log2(max|v|/Q))``) so that
dequantize(quantize(v)) is EXACT on already-on-grid values: ``q * 2^e`` is
exact in fp32 and dividing it back by any power-of-two scale yields an
integer, which both stochastic and nearest rounding map to itself. That
idempotency is what keeps the simulation deployment-faithful — the LBG
bank holds the dequantized grid values a real server would have stored at
the last full round, and a scalar round's ``rho_q * bank`` reconstruction
matches the server's bit-for-bit no matter how often the payload path
re-encodes it.

Stochastic rounding (``E[q] = f``) consumes one uint32 seed per client per
round, drawn host-side from the dedicated :func:`codec_rng` stream and
riding the batch dict under the reserved ``WIRE_KEY`` — the same seam the
attack extras use — so the batch/mask rng stream is untouched and a
``codec="none"`` run draws nothing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.registry import CODECS, register_codec

#: reserved batch-dict key for the per-client stochastic-rounding seed
WIRE_KEY = "_wire_seed"

#: e4m3 largest finite magnitude (S.1111.110 = 1.75 * 2^8)
E4M3_MAX = 448.0

# fp8 storage dtype for the wire representation; fall back to fp32 (the
# grid values are identical — only the buffer dtype widens) on jax builds
# without ml_dtypes' float8
_F8 = getattr(jnp, "float8_e4m3fn", jnp.float32)


def codec_rng(seed: int) -> np.random.RandomState:
    """Dedicated host rng stream for stochastic-rounding seeds.

    Like :func:`repro.fed.attacks.fault_rng`, a deterministic transform of
    the experiment seed that is de-correlated from both the batch/mask
    stream and the fault stream, so toggling the codec never shifts any
    other draw."""
    return np.random.RandomState((seed + 0xC0DEC) * 16807 % (2 ** 31))


# ------------------------------------------------------------ primitives

def stochastic_round(f, u):
    """Unbiased rounding of ``f`` to the integer grid: ``E[out] = f``.

    ``u`` is uniform on [0, 1). Exact integers round to themselves for
    every ``u`` (frac = 0 never exceeds u) — the idempotency workhorse."""
    lo = jnp.floor(f)
    return lo + (u < (f - lo))


def pow2_scale(m, qmax):
    """Smallest power-of-two ``s`` with ``m / s <= qmax`` (elementwise).

    Power-of-two, not ``m / qmax``: multiplying the integer/e4m3 grid back
    by ``s`` is then exact in fp32, giving the exact-requantization
    property the module docstring relies on. The power is materialized
    with ``ldexp`` on the integer exponent — ``exp2`` lowers to
    ``exp(x*ln2)`` on some backends and lands 1 ulp off a true power of
    two, which would silently void that exactness. All-zero rows get
    s = 1."""
    e = jnp.ceil(jnp.log2(jnp.maximum(m, 1e-38) / qmax)).astype(jnp.int32)
    s = jnp.ldexp(jnp.ones_like(m, jnp.float32), e)
    return jnp.where(m > 0, s, 1.0)


def e4m3_nearest(x):
    """Round-to-nearest e4m3 value of ``x`` (saturating), as fp32.

    Used for the scalar-round rho stream: one byte on the wire, and the
    aggregate applies exactly the value the server would decode."""
    return (jnp.clip(x, -E4M3_MAX, E4M3_MAX)
            .astype(_F8).astype(jnp.float32))


def _e4m3_step(a):
    """Grid spacing of e4m3 at magnitude ``a`` (a >= 0, fp32).

    Exponent comes from the IEEE bit pattern (exact — no log rounding),
    clipped to e4m3's normal range [-6, 8]; below 2^-6 the grid is the
    denormal ladder with constant step 2^-9."""
    e = ((jax.lax.bitcast_convert_type(a, jnp.int32) >> 23) & 0xFF) - 127
    return jnp.exp2((jnp.clip(e, -6, 8) - 3).astype(jnp.float32))


def delta_idx_bytes(idx):
    """Wire bytes of the varint-delta index stream for one sparse leaf.

    ``idx``: (..., kb) int32, block-local (< 2^16). Per row the indices
    are sorted ascending and sent as first-index-then-deltas, each delta
    as a varint (1/2/3 bytes). Lossless by construction — sorting loses
    nothing because ``(idx, val)`` pairs travel together and scatter-add
    is order-free within a row. Degenerate kb = 1 rows cost exactly one
    varint (the first index, delta from 0); pad rows (iota indices) are
    all-ones deltas, 1 byte each — counted like any other row, matching
    the fp32-scalar accounting which also prices pad rows."""
    s = jnp.sort(idx, axis=-1)
    prev = jnp.concatenate(
        [jnp.zeros_like(s[..., :1]), s[..., :-1]], axis=-1)
    d = s - prev
    return jnp.sum(1.0 + (d >= (1 << 7)) + (d >= (1 << 14)))


# ----------------------------------------------------------- codec base

class WireCodec:
    """Base codec: the fp32 legacy wire format.

    Subclasses override the class attributes (byte model) and, for lossy
    codecs, :meth:`quantize`. The engine calls :meth:`encode_sparse` /
    :meth:`encode_dense` at the tail of ``client_fn`` — after the uplink
    pipeline and the LBGM store step, i.e. on exactly what would be
    serialized — and the aggregator seam dequantizes via
    :meth:`decode_leaf` (or the fused dequant-accumulate kernel).
    """

    name = "none"
    lossy = False          # value quantization active
    stochastic = False     # consumes a per-client rounding seed
    delta_idx = False      # varint-delta index stream vs raw int32
    value_bytes = 4.0      # per transmitted payload value
    scalar_bytes = 4.0     # per scalar-round rho
    scale_bytes = 0.0      # per block row (sparse) / per leaf (dense)
    #: sparse payload leaf keys the aggregator seam sees
    payload_keys = ("idx", "val")

    # ------------------------------------------------------- byte model
    def sparse_full_bytes(self, send):
        """Full-round wire bytes of a sparse ``{name: {idx, val, ...}}``
        payload (one client). For non-delta codecs this is a static
        constant — no payload data is read."""
        total = jnp.zeros((), jnp.float32)
        for sk in send.values():
            idx = sk["idx"]
            nk, nb = float(idx.size), float(idx.shape[0])
            ib = delta_idx_bytes(idx) if self.delta_idx else 4.0 * nk
            total = total + ib + self.value_bytes * nk \
                + self.scale_bytes * nb
        return total

    def sparse_layout_bytes(self, layouts):
        """Static full-round wire bytes for a ``[(nb, kb), ...]`` block
        layout. The legacy dense-aggregation oracle path
        (``fused_kernels=False`` over a top-k store) ships the same
        conceptual (idx, val) payload as the sparse path but never
        materializes the indices, so data-dependent delta coding cannot
        apply there: indices price at the raw 4 bytes. For non-delta
        codecs this equals :meth:`sparse_full_bytes` exactly — the two
        aggregation paths report identical histories."""
        return float(sum((self.value_bytes + 4.0) * nb * kb
                         + self.scale_bytes * nb for nb, kb in layouts))

    # --------------------------------------------------------- encoding
    def encode_sparse(self, out, new_lbg, stats, seed):
        """Encode one client's sparse ``((send, gscale))`` payload.

        Returns ``(out, new_lbg, wire_bytes)``. The base (lossless)
        codecs leave payload and bank untouched — bit-for-bit."""
        del seed
        wire = jnp.where(stats.sent_scalar, self.scalar_bytes,
                         self.sparse_full_bytes(out[0]))
        return out, new_lbg, wire

    def encode_dense(self, gt, cost, seed):
        """Encode one client's dense update tree; ``cost`` is the uplink
        pipeline's fp32-scalar count. Returns ``(gt, wire_bytes)``."""
        del seed
        return gt, 4.0 * cost

    # --------------------------------------------------------- decoding
    def decode_leaf(self, sk):
        """fp32 values of one sparse payload leaf (the seam's 'decode')."""
        return sk["val"]


@register_codec("none")
class NoneCodec(WireCodec):
    pass


@register_codec("delta_idx")
class DeltaIdxCodec(WireCodec):
    name = "delta_idx"
    delta_idx = True


class _QuantizedCodec(WireCodec):
    """Shared machinery for the lossy value codecs."""

    lossy = True
    delta_idx = True
    value_bytes = 1.0
    scalar_bytes = 1.0     # rho as e4m3
    scale_bytes = 4.0
    payload_keys = ("idx", "val", "scale")
    wire_dtype = jnp.int8
    qmax = 127.0

    def __init__(self, stochastic: bool = True):
        self.stochastic = bool(stochastic)

    def _key(self, seed):
        """Per-client PRNG key, or None when rounding deterministically
        (no seed rides the batch dict then)."""
        return jax.random.PRNGKey(seed) if self.stochastic else None

    @staticmethod
    def _fold(key, i):
        return None if key is None else jax.random.fold_in(key, i)

    def _round(self, f, key):
        if self.stochastic:
            return stochastic_round(f, jax.random.uniform(key, f.shape))
        return jnp.round(f)

    def quantize(self, val, key):
        """(rows, cols) fp32 -> (wire-dtype grid, (rows, 1) fp32 scale)."""
        raise NotImplementedError

    def decode_leaf(self, sk):
        return sk["val"].astype(jnp.float32) * sk["scale"]

    def encode_sparse(self, out, new_lbg, stats, seed):
        send, gscale = out
        key = self._key(seed)
        send2, lbg2 = {}, {}
        for i, name in enumerate(sorted(send)):
            sk = send[name]
            q, scale = self.quantize(sk["val"], self._fold(key, i))
            send2[name] = {"idx": sk["idx"], "val": q, "scale": scale}
            # the bank keeps the DEQUANTIZED grid values: on a full round
            # send.val and new_lbg.val are the same keep_val array, so
            # applying the identical transform keeps client bank == what
            # the server decoded; on a recycle round the bank values are
            # already on the grid and the transform is exactly identity
            lbg2[name] = {"idx": new_lbg[name]["idx"],
                          "val": q.astype(jnp.float32) * scale}
        gscale_q = jnp.where(stats.sent_scalar,
                             e4m3_nearest(gscale), gscale)
        wire = jnp.where(stats.sent_scalar, self.scalar_bytes,
                         self.sparse_full_bytes(send2))
        return (send2, gscale_q), lbg2, wire

    def encode_dense(self, gt, cost, seed):
        del cost  # the codec ships the dense tree itself: M values + scales
        key = self._key(seed)
        out, total = {}, 0.0
        for i, name in enumerate(sorted(gt)):
            leaf = gt[name]
            q, scale = self.quantize(
                leaf.astype(jnp.float32).reshape(1, -1),
                self._fold(key, i))
            # dense aggregation consumes fp32 trees — dequantize here
            # (fusion into the aggregator is the sparse path's job)
            out[name] = (q.astype(jnp.float32) * scale).reshape(leaf.shape)
            total += self.value_bytes * leaf.size + self.scale_bytes
        return out, jnp.full((), total, jnp.float32)


@register_codec("int8")
class Int8Codec(_QuantizedCodec):
    name = "int8"

    def quantize(self, val, key):
        m = jnp.max(jnp.abs(val), axis=-1, keepdims=True)
        scale = pow2_scale(m, self.qmax)
        q = self._round(val / scale, key)
        # pow2_scale guarantees |val/scale| <= qmax up to log2 rounding
        # fuzz; clamp so that fuzz can never wrap the int8 cast
        q = jnp.clip(q, -self.qmax, self.qmax)
        return q.astype(self.wire_dtype), scale


@register_codec("fp8")
class Fp8Codec(_QuantizedCodec):
    name = "fp8"
    wire_dtype = _F8
    qmax = E4M3_MAX

    def quantize(self, val, key):
        m = jnp.max(jnp.abs(val), axis=-1, keepdims=True)
        scale = pow2_scale(m, self.qmax)
        x = val / scale
        a = jnp.abs(x)
        step = _e4m3_step(a)
        # round the mantissa-scaled magnitude on its local grid; crossing
        # up into the next binade lands on that binade's grid (f = 16
        # -> 8 * 2*step), so every outcome is e4m3-representable
        r = self._round(a / step, key)
        xq = jnp.clip(jnp.sign(x) * r * step, -self.qmax, self.qmax)
        return xq.astype(self.wire_dtype), scale


# ------------------------------------------------------------- resolver

def make_codec(cfg) -> WireCodec:
    """Resolve ``cfg.codec`` / ``cfg.codec_kw`` through the registry."""
    try:
        return CODECS.get(cfg.codec)(**(cfg.codec_kw or {}))
    except TypeError as e:
        raise ValueError(
            f"codec {cfg.codec!r} rejected codec_kw={cfg.codec_kw!r}: {e}"
        ) from e
