"""Synthetic datasets (offline container — no MNIST/CIFAR/CelebA).

* mixture classification: 28x28 "images" from per-class Gaussian prototypes —
  a learnable stand-in for the paper's MNIST/FMNIST experiments.
* markov LM: token streams from a random sparse Markov chain — learnable
  next-token structure for the assigned LM architectures.
* linear regression: CelebA-landmark-style regression stand-in.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

IMG = 28


def mixture_classification(n: int, num_classes: int = 10, seed: int = 0,
                           noise: float = 0.35):
    rng = np.random.RandomState(seed)
    protos = rng.randn(num_classes, IMG, IMG, 1).astype(np.float32)
    protos /= np.linalg.norm(protos.reshape(num_classes, -1),
                             axis=1).reshape(-1, 1, 1, 1)
    protos *= IMG  # unit-ish per-pixel scale
    y = rng.randint(0, num_classes, size=n).astype(np.int32)
    x = protos[y] + noise * rng.randn(n, IMG, IMG, 1).astype(np.float32)
    return x, y


def markov_lm(n_seqs: int, seq_len: int, vocab: int, seed: int = 0,
              branching: int = 4):
    """Each token has `branching` likely successors — learnable structure."""
    rng = np.random.RandomState(seed)
    nxt = rng.randint(0, vocab, size=(vocab, branching))
    toks = np.empty((n_seqs, seq_len + 1), np.int32)
    toks[:, 0] = rng.randint(0, vocab, size=n_seqs)
    choices = rng.randint(0, branching, size=(n_seqs, seq_len))
    for t in range(seq_len):
        toks[:, t + 1] = nxt[toks[:, t], choices[:, t]]
    return toks[:, :-1], toks[:, 1:]  # inputs, labels


def linear_regression(n: int, dim: int = 64, targets: int = 10, seed: int = 0,
                      noise: float = 0.05):
    rng = np.random.RandomState(seed)
    w = rng.randn(dim, targets).astype(np.float32) / np.sqrt(dim)
    x = rng.randn(n, dim).astype(np.float32)
    y = x @ w + noise * rng.randn(n, targets).astype(np.float32)
    return x, y
