"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lbgm_projection_ref(g: jax.Array, l: jax.Array):
    g32 = g.astype(jnp.float32)
    l32 = l.astype(jnp.float32)
    return jnp.dot(g32, l32), jnp.dot(g32, g32), jnp.dot(l32, l32)


def lbgm_sparse_decision_ref(blocks: jax.Array, idx: jax.Array):
    """The three dense passes the fused sparse kernel replaces.
    blocks: (nb, block) f32; idx: (nb, kb) int32 block-local positions.
    Returns (gg scalar, gathered (nb, kb), top_idx (nb, kb), top_val
    (nb, kb)) — top-k is by |value| per block row, values kept signed.
    """
    b32 = blocks.astype(jnp.float32)
    gg = jnp.sum(b32 * b32)
    gathered = jnp.take_along_axis(b32, idx, axis=1)
    kb = idx.shape[1]
    _, ti = jax.lax.top_k(jnp.abs(b32), kb)
    tv = jnp.take_along_axis(b32, ti, axis=1)
    return gg, gathered, ti.astype(jnp.int32), tv


def lbgm_dequant_accum_ref(acc: jax.Array, w: jax.Array, gscale: jax.Array,
                           idx: jax.Array, qv: jax.Array, scale: jax.Array):
    """Sequential dequantize + scatter-accumulate (the fused kernel's
    oracle, and the engine's XLA fallback for quantized payloads).

    acc: (nb, block) f32; w, gscale: (C,); idx: (C, nb, kb) int32; qv:
    (C, nb, kb) wire-dtype values; scale: (C, nb, 1) f32 row scales.
    Gather-modify-scatter with ``coeff = (w * gscale) * scale`` folded
    before the multiply with the widened values — the same op order as
    the kernel, and the same ``a + where(w > 0, c * v, 0)`` shape as
    ``SparseTopKAggregator`` so full-round aggregates stay bit-equal to
    the unquantized path when the values are on the fp32 grid already.
    """
    def body(a, x):
        w_k, g_k, i_k, q_k, s_k = x
        rows = jnp.arange(a.shape[0])[:, None]
        coeff = (w_k * g_k) * s_k                        # (nb, 1)
        cur = a[rows, i_k]
        new = cur + jnp.where(w_k > 0,
                              coeff * q_k.astype(jnp.float32), 0.0)
        return a.at[rows, i_k].set(new), None

    out, _ = jax.lax.scan(body, acc, (w, gscale, idx, qv, scale))
    return out


def sort_topk_rows(idx: jax.Array, val: jax.Array):
    """Canonicalize a block-row top-k (idx, val) pair by ascending index.

    The one-pass kernel emits entries in descending-|value| order
    (``lax.top_k``), the two-pass threshold-select variant in index
    order; consumers treat each row as a set, so equivalence tests
    compare through this canonical form.
    """
    order = jnp.argsort(idx, axis=-1)
    return (jnp.take_along_axis(idx, order, axis=-1),
            jnp.take_along_axis(val, order, axis=-1))


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """Naive softmax attention. q:(BH,Tq,hd), k/v:(BH,Tk,hd)."""
    Tq, Tk = q.shape[1], k.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, logw, u):
    """Per-timestep recurrence — the ground-truth RWKV6 semantics.
    r,k,v,logw: (BH, T, hd); u: (BH, hd). Returns fp32 (BH, T, hd).

        out_t = r_t (S_{t-1} + u * k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    r, k, v, lw = (a.astype(jnp.float32) for a in (r, k, v, logw))
    u = u.astype(jnp.float32)
    BH, T, hd = r.shape

    def step(S, xs):
        rt, kt, vt, lwt = xs                     # (BH, hd)
        kv = jnp.einsum("bd,be->bde", kt, vt)
        out = jnp.einsum("bd,bde->be", rt, S + u[..., None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, out

    S0 = jnp.zeros((BH, hd, hd), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2) for a in (r, k, v, lw))
    _, outs = jax.lax.scan(step, S0, xs)
    return outs.transpose(1, 0, 2)
