"""RWKV6 chunked recurrence — TPU Pallas.

Grid (B*H, T/CHUNK); the chunk axis is innermost/sequential, carrying the
(hd, hd) fp32 state in VMEM scratch across chunks. Each step loads one
(CHUNK, hd) tile of r/k/v/logw, computes the intra-chunk masked interaction
matrix on the MXU and the cross-chunk contribution from the carried state
(same math as repro.models.rwkv6.chunked_wkv; oracle = per-step recurrence in
ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 64


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                  c: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)           # (c, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = w_ref[0].astype(jnp.float32)          # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)           # (1, hd)

    cum = jnp.cumsum(lw, axis=0)
    cum_in = cum - lw
    r_dec = r * jnp.exp(cum_in)
    k_dec = k * jnp.exp(jnp.minimum(-cum, 60.0))  # overflow clamp (see models.rwkv6)
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    a = jnp.dot(r_dec, k_dec.T, preferred_element_type=jnp.float32)
    a = jnp.where(tri, a, 0.0)
    diag = jnp.sum(r * u * k, axis=1)          # (c,)
    out = jnp.dot(a, v, preferred_element_type=jnp.float32)
    out += diag[:, None] * v
    out += jnp.dot(r_dec, s_ref[...], preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)

    total = cum[-1:, :]                        # (1, hd)
    s_ref[...] = s_ref[...] * jnp.exp(total).T + jnp.dot(
        (k * jnp.exp(total - cum)).T, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_scan_pallas(r, k, v, logw, u, interpret=None):
    """r,k,v,logw: (BH, T, hd); u: (BH, hd). Returns fp32 (BH, T, hd).

    ``interpret=None`` auto-detects the backend (compiled Mosaic on TPU,
    interpreter elsewhere), matching the ``ops.py`` wrappers."""
    if interpret is None:
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    BH, T, hd = r.shape
    c = min(CHUNK, T)
    assert T % c == 0
    kernel = functools.partial(_rwkv6_kernel, c=c)
    return pl.pallas_call(
        kernel,
        grid=(BH, T // c),
        in_specs=[
            pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, hd), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
