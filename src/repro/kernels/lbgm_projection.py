"""Fused LBGM projection kernel (TPU Pallas).

The paper's per-round hot spot is three O(M) reductions over the flattened
gradient g and look-back gradient l: <g,l>, ||g||^2, ||l||^2 (Algorithm 1
steps 6 & 8). Done naively that is 3 separate HBM passes over 2 vectors; this
kernel fuses them into ONE pass (each operand read exactly once), with
(BLOCK_R, 128)-tiled VMEM blocks and a running fp32 accumulator in the output
block (TPU grid is sequential, so across-step accumulation into the same
output block is well-defined).

Two entry points:

* :func:`lbgm_projection_pallas` — one (g, l) pair of flat vectors.
* :func:`lbgm_projection_batched_pallas` — a stack of B pairs with a LEADING
  BATCH GRID DIMENSION ``grid=(B, tiles)``: the client axis of the FL
  engine's schedulers maps straight onto grid dim 0, so one ``pallas_call``
  covers a whole vmap'd client block (``kernels.ops.lbgm_projection``
  routes ``jax.vmap`` here through a ``custom_vmap`` rule). The tile loop
  (dim 1) is innermost, so the per-row accumulator init at ``tile == 0``
  stays correct under the sequential TPU grid.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 64      # sublane-tiled rows per grid step
LANES = 128       # TPU lane width


def _proj_kernel(g_ref, l_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)
    l = l_ref[...].astype(jnp.float32)
    gl = jnp.sum(g * l)
    gg = jnp.sum(g * g)
    ll = jnp.sum(l * l)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    vec = (jnp.where(lane == 0, gl, 0.0) + jnp.where(lane == 1, gg, 0.0)
           + jnp.where(lane == 2, ll, 0.0))
    out_ref[...] += vec


@functools.partial(jax.jit, static_argnames=("interpret",))
def lbgm_projection_pallas(g: jax.Array, l: jax.Array,
                           interpret: Optional[bool] = None):
    """g, l: flat 1-D arrays (any float dtype), same length.
    Returns (gl, gg, ll) fp32 scalars.

    ``interpret=None`` auto-detects the backend (compiled Mosaic on TPU,
    interpreter elsewhere) — same policy as the ``ops.py`` wrappers, so
    direct callers no longer silently run the interpreter on real TPUs.
    """
    if interpret is None:
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    assert g.ndim == 1 and g.shape == l.shape
    n = g.shape[0]
    tile = BLOCK_R * LANES
    pad = (-n) % tile
    if pad:
        g = jnp.pad(g, (0, pad))
        l = jnp.pad(l, (0, pad))
    rows = (n + pad) // LANES
    g2 = g.reshape(rows, LANES)
    l2 = l.reshape(rows, LANES)
    grid = rows // BLOCK_R
    out = pl.pallas_call(
        _proj_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, LANES), jnp.float32),
        interpret=interpret,
    )(g2, l2)
    return out[0, 0], out[0, 1], out[0, 2]


def _proj_kernel_batched(g_ref, l_ref, out_ref):
    # grid = (B, tiles); dim 1 (tiles) is innermost, so each batch row's
    # accumulator is initialized once and then swept over all of its tiles
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)
    l = l_ref[...].astype(jnp.float32)
    gl = jnp.sum(g * l)
    gg = jnp.sum(g * g)
    ll = jnp.sum(l * l)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    vec = (jnp.where(lane == 0, gl, 0.0) + jnp.where(lane == 1, gg, 0.0)
           + jnp.where(lane == 2, ll, 0.0))
    out_ref[...] += vec


@functools.partial(jax.jit, static_argnames=("interpret",))
def lbgm_projection_batched_pallas(g: jax.Array, l: jax.Array,
                                   interpret: Optional[bool] = None):
    """g, l: (B, n) stacks of flat vectors (any float dtype).
    Returns (gl, gg, ll) fp32 arrays of shape (B,) — one fused pass per row.

    The batch axis is grid dimension 0, so the same compiled kernel serves
    any client-block size; each row accumulates into its own (1, LANES)
    output block exactly like the unbatched kernel.
    """
    if interpret is None:
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    assert g.ndim == 2 and g.shape == l.shape
    B, n = g.shape
    tile = BLOCK_R * LANES
    pad = (-n) % tile
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
        l = jnp.pad(l, ((0, 0), (0, pad)))
    rows = (n + pad) // LANES
    g3 = g.reshape(B, rows, LANES)
    l3 = l.reshape(B, rows, LANES)
    tiles = rows // BLOCK_R
    out = pl.pallas_call(
        _proj_kernel_batched,
        grid=(B, tiles),
        in_specs=[
            pl.BlockSpec((1, BLOCK_R, LANES), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, BLOCK_R, LANES), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda b, i: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, LANES), jnp.float32),
        interpret=interpret,
    )(g3, l3)
    return out[:, 0], out[:, 1], out[:, 2]
