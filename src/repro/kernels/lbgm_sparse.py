"""Fused sparse-LBG decision kernel (TPU Pallas).

The sparse (top-k) Algorithm-1 client step in ``core/lbgm.py`` makes THREE
separate passes over each dense gradient leaf per client:

1. ``leaf_sparse_gather`` — g's values at the stored LBG positions,
2. ``jnp.vdot(g, g)``     — the squared norm for the sin^2 test,
3. ``leaf_topk``          — |g| + block-wise top-k for the refresh branch.

This kernel fuses all three into ONE pass over the (nb, block) block layout:
each grid step reads one block row of g exactly once and emits that row's
partial ||g||^2, the gathered values at the LBG's block-local indices, and
the row's top-k candidates (signed values + indices). The engine-facing
entry has a LEADING BATCH GRID DIMENSION ``grid=(B, nb)`` so the client
axis of a vmap'd scheduler block maps straight onto grid dim 0
(``kernels.ops.lbgm_sparse_decision`` routes ``jax.vmap`` here via a
``custom_vmap`` rule); ``nb`` is innermost so the per-row ||g||^2
accumulator init at ``row == 0`` is correct under the sequential TPU grid.

Validated against ``kernels/ref.py`` in interpret mode (tests); on TPU the
win is structural — one HBM read of g instead of three.

Mosaic-safety fallback (ROADMAP open item): the default kernel leans on
``lax.top_k`` and ``take_along_axis`` *inside* the kernel body, whose
Mosaic lowering has not been exercised on real TPU hardware. The
``two_pass`` variant below removes both: pass 1 bisects the per-row
top-k |value| threshold exactly — in int32 IEEE bit space, so every
magnitude regime resolves — with nothing but bitcasts, compares and
sums; pass 2
compacts the selected entries (and gathers the LBG positions) with tiled
one-hot matmuls — iota / compare / select / dot / fori_loop only, the
op set Mosaic lowers everywhere. Same one-HBM-read structure, same
outputs as a *set* per row (slot order is by index, not descending
value; every consumer treats the (idx, val) pairs as a set). Enable with
``REPRO_LBGM_TWO_PASS_TOPK=1`` (see ``kernels.ops.lbgm_sparse_decision``)
if the default kernel fails to compile or mis-lowers on hardware.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sparse_decision_kernel(g_ref, idx_ref, gg_ref, gath_ref, ti_ref,
                            tv_ref):
    # grid = (B, nb); dim 1 (block rows) is innermost
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        gg_ref[...] = jnp.zeros_like(gg_ref)

    g = g_ref[...].reshape(1, -1).astype(jnp.float32)   # (1, block)
    idx = idx_ref[...].reshape(1, -1)                   # (1, kb)
    kb = idx.shape[1]
    # one read of g feeds all three outputs
    gg_ref[...] += jnp.sum(g * g).reshape(1, 1)
    gath_ref[...] = jnp.take_along_axis(g, idx, axis=1).reshape(1, 1, kb)
    _, ti = jax.lax.top_k(jnp.abs(g), kb)
    ti_ref[...] = ti.astype(jnp.int32).reshape(1, 1, kb)
    tv_ref[...] = jnp.take_along_axis(g, ti, axis=1).reshape(1, 1, kb)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lbgm_sparse_decision_batched_pallas(blocks: jax.Array, idx: jax.Array,
                                        interpret: Optional[bool] = None):
    """blocks: (B, nb, block) f32 block-layout gradients; idx: (B, nb, kb)
    int32 block-local LBG positions. Returns
    ``(gg (B,), gathered (B, nb, kb), top_idx (B, nb, kb) int32,
    top_val (B, nb, kb) f32)`` — each client's row of g read exactly once.
    """
    if interpret is None:
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    assert blocks.ndim == 3 and idx.ndim == 3
    assert blocks.shape[:2] == idx.shape[:2]
    B, nb, block = blocks.shape
    kb = idx.shape[2]
    gg, gath, ti, tv = pl.pallas_call(
        _sparse_decision_kernel,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, 1, block), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, nb, kb), jnp.float32),
            jax.ShapeDtypeStruct((B, nb, kb), jnp.int32),
            jax.ShapeDtypeStruct((B, nb, kb), jnp.float32),
        ],
        interpret=interpret,
    )(blocks, idx)
    return gg[:, 0], gath, ti, tv


def lbgm_sparse_decision_pallas(blocks: jax.Array, idx: jax.Array,
                                interpret: Optional[bool] = None):
    """Unbatched view of the fused decision: blocks (nb, block),
    idx (nb, kb) -> (gg scalar, gathered, top_idx, top_val)."""
    gg, gath, ti, tv = lbgm_sparse_decision_batched_pallas(
        blocks[None], idx[None], interpret=interpret)
    return gg[0], gath[0], ti[0], tv[0]


# ---------------------------------------- two-pass threshold-select variant

#: pass-2 compaction tile (lanes per one-hot matmul); multiples of 128
#: keep the dynamic lane slices MXU/VPU aligned
TWO_PASS_TILE = 512
#: bisection steps for the per-row top-k threshold. The bisection runs on
#: the int32 IEEE bit patterns of |g| (monotone in value for non-negative
#: floats), so 32 integer halvings of [-1, bits(max)] always terminate
#: with lo/hi ADJACENT — hi is exactly the kb-th largest |value|'s bit
#: pattern and the tie band holds only exact ties, at every magnitude
#: (a float-interval bisection has absolute resolution ~max/2^iters and
#: silently mis-selects rows whose |values| all sit below it)
TWO_PASS_BISECT_ITERS = 32


def _two_pass_kernel(g_ref, idx_ref, gg_ref, gath_ref, ti_ref, tv_ref, *,
                     tile: int, iters: int):
    """Sort-free / gather-free fused decision (see module docstring).

    Per (client, block-row) grid step: bisect the row's kb-th largest
    |value| (pass 1: compares + sums only), then one tiled sweep (pass 2)
    emits the compacted top-k entries, the values gathered at the LBG
    positions, and the row's ||g||^2 partial — all through one-hot
    matmuls, so nothing in the body needs a sort or a dynamic gather.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        gg_ref[...] = jnp.zeros_like(gg_ref)

    g = g_ref[...].reshape(1, -1).astype(jnp.float32)   # (1, Bp)
    idx = idx_ref[...].reshape(1, -1)                   # (1, kb)
    kb = idx.shape[1]
    Bp = g.shape[1]
    a = jnp.abs(g)
    gg_ref[...] += jnp.sum(g * g).reshape(1, 1)

    # ---- pass 1: bisect t* (the kb-th largest |value|) into (lo, hi] —
    # in IEEE BIT space: for non-negative f32 the int32 bit pattern is
    # monotone in value, so integer halvings of [-1, bits(max)] converge
    # to ADJACENT lo/hi in <= 32 steps. hi is then exactly t*'s bit
    # pattern: the "tie band" (lo, hi] holds only exact t* ties, for
    # subnormal-scale rows as much as unit-scale ones. Invariant:
    # count(ai > lo) >= kb > count(ai > hi) (ai >= 0 everywhere, so the
    # initial lo = -1 count is Bp >= kb; count(ai > max) == 0 < kb).
    ai = jax.lax.bitcast_convert_type(a, jnp.int32)     # (1, Bp), >= 0

    def bis(_, lh):
        lo, hi = lh
        # lo + (hi - lo)//2, NOT (lo + hi)//2: bit patterns of values
        # >= 2.0 exceed 2^30, so the naive midpoint overflows int32
        mid = lo + (hi - lo) // 2
        big = jnp.sum((ai > mid).astype(jnp.float32)) >= kb
        return (jnp.where(big, mid, lo), jnp.where(big, hi, mid))

    lo, hi = jax.lax.fori_loop(
        0, iters, bis, (jnp.int32(-1), jnp.max(ai)))
    # "definite" entries sit strictly above the band; ties (== t*) fill
    # the remaining slots in index order — exactly lax.top_k's
    # lowest-index tie rule, and for rows with fewer than kb nonzeros the
    # band is the zeros, so every nonzero is still kept
    m = jnp.sum((ai > hi).astype(jnp.float32))          # < kb by invariant

    # ---- pass 2: tiled compaction + gather (one-hot matmuls)
    n_tiles = Bp // tile
    # inclusive-cumsum operator: mask (1, T) @ tri (T, T) with
    # tri[i, j] = (i <= j)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 0)
           <= jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 1)
           ).astype(jnp.float32)
    slot_iota = jax.lax.broadcasted_iota(jnp.float32, (tile, kb), 1)
    lane_iota = jax.lax.broadcasted_iota(jnp.float32, (1, tile), 1)
    idx_f = idx.astype(jnp.float32)

    def tl(t, carry):
        cdef, ctie, tiv, tvv, gv = carry
        g_t = jax.lax.dynamic_slice(g, (0, t * tile), (1, tile))
        # classify in the same bit space the threshold lives in (lo may
        # be -1, which is not a valid float to compare against)
        ai_t = jax.lax.bitcast_convert_type(jnp.abs(g_t), jnp.int32)
        dmask = (ai_t > hi).astype(jnp.float32)         # (1, T)
        smask = ((ai_t > lo) & (ai_t <= hi)).astype(jnp.float32)
        cum_d = jax.lax.dot(dmask, tri) + cdef          # running 1-indexed
        cum_t = jax.lax.dot(smask, tri) + ctie          # rank per class
        # output slot (1-indexed; 0 = unselected): definites first (their
        # global count m < kb), then ties; slots > kb match no one-hot
        # column below, which is the cap
        slot = dmask * cum_d + smask * (m + cum_t)
        oh = ((slot[0][:, None] == slot_iota + 1.0)
              & ((dmask + smask)[0][:, None] > 0)).astype(jnp.float32)
        pos = jnp.float32(t * tile) + lane_iota         # global positions
        tvv = tvv + jax.lax.dot(g_t, oh)                # (1, kb)
        tiv = tiv + jax.lax.dot(pos, oh)
        # gather at the LBG positions: positions < 2^24, exact in f32
        oh2 = (pos[0][:, None] == idx_f[0][None, :]).astype(jnp.float32)
        gv = gv + jax.lax.dot(g_t, oh2)
        return (cdef + jnp.sum(dmask), ctie + jnp.sum(smask), tiv, tvv, gv)

    zk = jnp.zeros((1, kb), jnp.float32)
    _, _, tiv, tvv, gv = jax.lax.fori_loop(
        0, n_tiles, tl, (jnp.float32(0), jnp.float32(0), zk, zk, zk))
    gath_ref[...] = gv.reshape(1, 1, kb)
    ti_ref[...] = tiv.astype(jnp.int32).reshape(1, 1, kb)
    tv_ref[...] = tvv.reshape(1, 1, kb)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lbgm_sparse_decision_two_pass_batched_pallas(
        blocks: jax.Array, idx: jax.Array,
        interpret: Optional[bool] = None):
    """Two-pass threshold-select twin of
    :func:`lbgm_sparse_decision_batched_pallas` (same signature, same
    contract) with the per-row (idx, val) set emitted in *index* order
    instead of descending |value| — every consumer treats it as a set.

    The lane axis is zero-padded up to a tile multiple before the call;
    pass 1's strict compares never select a pad zero ahead of real data
    (pads sit at the highest positions, and a row holds at least
    ``block >= kb`` real entries), and pad contributions to ||g||^2 are
    exact zeros.
    """
    if interpret is None:
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    assert blocks.ndim == 3 and idx.ndim == 3
    assert blocks.shape[:2] == idx.shape[:2]
    B, nb, block = blocks.shape
    kb = idx.shape[2]
    tile = min(TWO_PASS_TILE, block)
    pad = (-block) % tile
    if pad:
        blocks = jnp.pad(blocks, ((0, 0), (0, 0), (0, pad)))
    Bp = block + pad
    kernel = functools.partial(_two_pass_kernel, tile=tile,
                               iters=TWO_PASS_BISECT_ITERS)
    gg, gath, ti, tv = pl.pallas_call(
        kernel,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, 1, Bp), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, nb, kb), jnp.float32),
            jax.ShapeDtypeStruct((B, nb, kb), jnp.int32),
            jax.ShapeDtypeStruct((B, nb, kb), jnp.float32),
        ],
        interpret=interpret,
    )(blocks, idx)
    return gg[:, 0], gath, ti, tv


def lbgm_sparse_decision_two_pass_pallas(blocks: jax.Array, idx: jax.Array,
                                         interpret: Optional[bool] = None):
    """Unbatched view of the two-pass fused decision."""
    gg, gath, ti, tv = lbgm_sparse_decision_two_pass_batched_pallas(
        blocks[None], idx[None], interpret=interpret)
    return gg[0], gath[0], ti[0], tv[0]


# ------------------------------------------ fused dequant + accumulate

def _dequant_accum_kernel(w_ref, gs_ref, idx_ref, qv_ref, sc_ref, acc_ref,
                          out_ref):
    """One (nb,) grid step folds all C clients' quantized payload rows
    into one accumulator block row.

    The payload values arrive in their WIRE dtype (int8 / fp8) and are
    widened client-by-client inside the kernel — the fused fast path
    never materializes an fp32 (C, nb, kb) payload buffer. The fold is a
    strictly sequential fori_loop (same client order as the XLA scan
    path) of gather-modify-scatter updates: coeff = (w * gscale) * scale
    is folded before the multiply with the quantized values, exactly the
    :func:`repro.kernels.ref.lbgm_dequant_accum_ref` op order, so the
    interpret-mode kernel is bit-identical to the oracle. The ``w > 0``
    gate keeps phantom pad clients' NaN payloads out of the aggregate
    (fp8 NaN widens to fp32 NaN — multiplying by a zero coeff is not
    enough).

    Mosaic caveat (same as the default decision kernel): the body uses
    ``take_along_axis``/``put_along_axis``; validated in interpret mode,
    structural one-HBM-pass win on TPU.
    """
    row = acc_ref[...].reshape(1, -1)                   # (1, block)
    C = qv_ref.shape[0]

    def fold(c, r):
        wc = w_ref[c, 0]
        coeff = (wc * gs_ref[c, 0]) * sc_ref[c, 0, 0]
        q = qv_ref[c].reshape(1, -1).astype(jnp.float32)  # (1, kb)
        ix = idx_ref[c].reshape(1, -1)
        cur = jnp.take_along_axis(r, ix, axis=1)
        new = cur + jnp.where(wc > 0, coeff * q, 0.0)
        return jnp.put_along_axis(r, ix, new, axis=1, inplace=False)

    out_ref[...] = jax.lax.fori_loop(0, C, fold, row).reshape(
        out_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lbgm_dequant_accum_pallas(acc: jax.Array, w: jax.Array,
                              gscale: jax.Array, idx: jax.Array,
                              qv: jax.Array, scale: jax.Array,
                              interpret: Optional[bool] = None):
    """Fused dequantize + scatter-accumulate for one quantized sparse leaf.

    acc: (nb, block) f32 accumulator; w, gscale: (C,) client weights and
    scalar-round multipliers; idx: (C, nb, kb) int32 block-local
    positions; qv: (C, nb, kb) wire-dtype quantized values; scale:
    (C, nb, 1) f32 per-block-row dequantization scales. Returns
    ``acc + sum_c [w_c > 0] (w_c * gscale_c * scale_c) * f32(qv_c)``
    scattered at ``idx_c``, clients folded in order. The accumulator
    input buffer is donated (``input_output_aliases``) — the carry is
    updated in place across the round's chunk scan.
    """
    if interpret is None:
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    assert idx.ndim == 3 and qv.shape == idx.shape
    C, nb, kb = idx.shape
    assert acc.shape[0] == nb and scale.shape == (C, nb, 1)
    block = acc.shape[1]
    w2 = w.reshape(C, 1).astype(jnp.float32)
    gs2 = gscale.reshape(C, 1).astype(jnp.float32)
    return pl.pallas_call(
        _dequant_accum_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((C, 1), lambda j: (0, 0)),
            pl.BlockSpec((C, 1), lambda j: (0, 0)),
            pl.BlockSpec((C, 1, kb), lambda j: (0, j, 0)),
            pl.BlockSpec((C, 1, kb), lambda j: (0, j, 0)),
            pl.BlockSpec((C, 1, 1), lambda j: (0, j, 0)),
            pl.BlockSpec((1, block), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        input_output_aliases={5: 0},
        interpret=interpret,
    )(w2, gs2, idx, qv, scale, acc)
