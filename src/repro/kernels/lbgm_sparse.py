"""Fused sparse-LBG decision kernel (TPU Pallas).

The sparse (top-k) Algorithm-1 client step in ``core/lbgm.py`` makes THREE
separate passes over each dense gradient leaf per client:

1. ``leaf_sparse_gather`` — g's values at the stored LBG positions,
2. ``jnp.vdot(g, g)``     — the squared norm for the sin^2 test,
3. ``leaf_topk``          — |g| + block-wise top-k for the refresh branch.

This kernel fuses all three into ONE pass over the (nb, block) block layout:
each grid step reads one block row of g exactly once and emits that row's
partial ||g||^2, the gathered values at the LBG's block-local indices, and
the row's top-k candidates (signed values + indices). The engine-facing
entry has a LEADING BATCH GRID DIMENSION ``grid=(B, nb)`` so the client
axis of a vmap'd scheduler block maps straight onto grid dim 0
(``kernels.ops.lbgm_sparse_decision`` routes ``jax.vmap`` here via a
``custom_vmap`` rule); ``nb`` is innermost so the per-row ||g||^2
accumulator init at ``row == 0`` is correct under the sequential TPU grid.

Validated against ``kernels/ref.py`` in interpret mode (tests); on TPU the
win is structural — one HBM read of g instead of three.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sparse_decision_kernel(g_ref, idx_ref, gg_ref, gath_ref, ti_ref,
                            tv_ref):
    # grid = (B, nb); dim 1 (block rows) is innermost
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        gg_ref[...] = jnp.zeros_like(gg_ref)

    g = g_ref[...].reshape(1, -1).astype(jnp.float32)   # (1, block)
    idx = idx_ref[...].reshape(1, -1)                   # (1, kb)
    kb = idx.shape[1]
    # one read of g feeds all three outputs
    gg_ref[...] += jnp.sum(g * g).reshape(1, 1)
    gath_ref[...] = jnp.take_along_axis(g, idx, axis=1).reshape(1, 1, kb)
    _, ti = jax.lax.top_k(jnp.abs(g), kb)
    ti_ref[...] = ti.astype(jnp.int32).reshape(1, 1, kb)
    tv_ref[...] = jnp.take_along_axis(g, ti, axis=1).reshape(1, 1, kb)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lbgm_sparse_decision_batched_pallas(blocks: jax.Array, idx: jax.Array,
                                        interpret: Optional[bool] = None):
    """blocks: (B, nb, block) f32 block-layout gradients; idx: (B, nb, kb)
    int32 block-local LBG positions. Returns
    ``(gg (B,), gathered (B, nb, kb), top_idx (B, nb, kb) int32,
    top_val (B, nb, kb) f32)`` — each client's row of g read exactly once.
    """
    if interpret is None:
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    assert blocks.ndim == 3 and idx.ndim == 3
    assert blocks.shape[:2] == idx.shape[:2]
    B, nb, block = blocks.shape
    kb = idx.shape[2]
    gg, gath, ti, tv = pl.pallas_call(
        _sparse_decision_kernel,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, 1, block), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, kb), lambda b, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, nb, kb), jnp.float32),
            jax.ShapeDtypeStruct((B, nb, kb), jnp.int32),
            jax.ShapeDtypeStruct((B, nb, kb), jnp.float32),
        ],
        interpret=interpret,
    )(blocks, idx)
    return gg[:, 0], gath, ti, tv


def lbgm_sparse_decision_pallas(blocks: jax.Array, idx: jax.Array,
                                interpret: Optional[bool] = None):
    """Unbatched view of the fused decision: blocks (nb, block),
    idx (nb, kb) -> (gg scalar, gathered, top_idx, top_val)."""
    gg, gath, ti, tv = lbgm_sparse_decision_batched_pallas(
        blocks[None], idx[None], interpret=interpret)
    return gg[0], gath[0], ti[0], tv[0]
