"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to ``None`` -> :func:`_default_interpret` backend
auto-detection (interpreter on CPU where the container validates kernel
bodies, compiled Mosaic on real TPUs). The raw ``*_pallas`` entry points in
the kernel modules share the same ``None`` default, so callers that bypass
these wrappers get compiled execution on TPU too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lbgm_projection import lbgm_projection_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def lbgm_projection(g_tree, l_tree, interpret=None):
    """Fused (<g,l>, ||g||^2, ||l||^2) over a pytree pair (one HBM pass per
    leaf). Returns fp32 scalars."""
    interpret = _default_interpret() if interpret is None else interpret
    gl = gg = ll = jnp.zeros((), jnp.float32)
    g_leaves = jax.tree.leaves(g_tree)
    l_leaves = jax.tree.leaves(l_tree)
    for g, l in zip(g_leaves, l_leaves):
        a, b, c = lbgm_projection_pallas(g.reshape(-1), l.reshape(-1),
                                         interpret=interpret)
        gl, gg, ll = gl + a, gg + b, ll + c
    return gl, gg, ll


def flash_attention(q, k, v, *, causal=True, window=None, interpret=None):
    """GQA flash attention. q:(B,Tq,Hq,hd), k/v:(B,Tk,Hkv,hd)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, Tq, Hq, hd = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Tq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(
        B * Hq, Tk, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(
        B * Hq, Tk, hd)
    o = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                               interpret=interpret)
    return o.reshape(B, Hq, Tq, hd).transpose(0, 2, 1, 3)


def rwkv6_scan(r, k, v, logw, u, interpret=None):
    """Chunked RWKV6. r/k/v/logw: (B,T,H,hd); u: (H,hd) -> fp32 (B,T,H,hd)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, T, H, hd = r.shape
    flat = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    o = rwkv6_scan_pallas(flat(r), flat(k), flat(v), flat(logw), uf,
                          interpret=interpret)
    return o.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
