"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to ``None`` -> :func:`_default_interpret` backend
auto-detection (interpreter on CPU where the container validates kernel
bodies, compiled Mosaic on real TPUs). The raw ``*_pallas`` entry points in
the kernel modules share the same ``None`` default, so callers that bypass
these wrappers get compiled execution on TPU too.

The two LBGM wrappers (:func:`lbgm_projection`,
:func:`lbgm_sparse_decision`) are the FL engine's fused decision hot path
(``FLConfig.fused_kernels``). Both carry a ``custom_vmap`` rule that maps
``jax.vmap`` — how every client scheduler batches the per-client step —
onto the kernels' leading batch grid dimension, so a vmap'd client block
compiles to ONE batched ``pallas_call`` instead of per-client dispatches.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lbgm_projection import (lbgm_projection_batched_pallas,
                                           lbgm_projection_pallas)
from repro.kernels.lbgm_sparse import (
    lbgm_dequant_accum_pallas, lbgm_sparse_decision_batched_pallas,
    lbgm_sparse_decision_pallas,
    lbgm_sparse_decision_two_pass_batched_pallas,
    lbgm_sparse_decision_two_pass_pallas)
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas

#: Mosaic-safety knob for the fused sparse decision: "1" routes
#: lbgm_sparse_decision through the two-pass threshold-select kernel
#: (no lax.top_k / take_along_axis inside the kernel body — see
#: kernels/lbgm_sparse.py). Flip it if the default kernel fails to
#: compile or mis-lowers on real TPU hardware; no config surgery needed.
TWO_PASS_ENV = "REPRO_LBGM_TWO_PASS_TOPK"


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _default_two_pass() -> bool:
    return os.environ.get(TWO_PASS_ENV, "0").lower() not in (
        "0", "", "false", "off", "no")


def _bcast(x, batched, axis_size):
    """custom_vmap hands unbatched args through unchanged; lift them."""
    return x if batched else jnp.broadcast_to(x, (axis_size,) + x.shape)


@functools.lru_cache(maxsize=None)
def _proj_leaf(interpret: bool):
    """Per-leaf fused projection with vmap routed to the batched kernel."""

    @custom_vmap
    def f(g, l):
        return lbgm_projection_pallas(g, l, interpret=interpret)

    @f.def_vmap
    def _rule(axis_size, in_batched, g, l):
        g = _bcast(g, in_batched[0], axis_size)
        l = _bcast(l, in_batched[1], axis_size)
        out = lbgm_projection_batched_pallas(g, l, interpret=interpret)
        return out, (True, True, True)

    return f


@functools.lru_cache(maxsize=None)
def _sparse_decision(interpret: bool, two_pass: bool):
    """Fused sparse decision with vmap routed to the batched kernel."""
    one = (lbgm_sparse_decision_two_pass_pallas if two_pass
           else lbgm_sparse_decision_pallas)
    batched = (lbgm_sparse_decision_two_pass_batched_pallas if two_pass
               else lbgm_sparse_decision_batched_pallas)

    @custom_vmap
    def f(blocks, idx):
        return one(blocks, idx, interpret=interpret)

    @f.def_vmap
    def _rule(axis_size, in_batched, blocks, idx):
        blocks = _bcast(blocks, in_batched[0], axis_size)
        idx = _bcast(idx, in_batched[1], axis_size)
        out = batched(blocks, idx, interpret=interpret)
        return out, (True, True, True, True)

    return f


def lbgm_projection(g_tree, l_tree, interpret=None):
    """Fused (<g,l>, ||g||^2, ||l||^2) over a pytree pair (one HBM pass per
    leaf). Returns fp32 scalars. vmap-ing this (the schedulers' client axis)
    compiles to the batched kernel, one leading grid dimension per leaf."""
    interpret = _default_interpret() if interpret is None else interpret
    f = _proj_leaf(bool(interpret))
    gl = gg = ll = jnp.zeros((), jnp.float32)
    g_leaves = jax.tree.leaves(g_tree)
    l_leaves = jax.tree.leaves(l_tree)
    for g, l in zip(g_leaves, l_leaves):
        a, b, c = f(g.reshape(-1), l.reshape(-1))
        gl, gg, ll = gl + a, gg + b, ll + c
    return gl, gg, ll


def lbgm_sparse_decision(blocks, idx, interpret=None, two_pass=None):
    """One fused pass over a (nb, block) gradient block layout: returns
    ``(gg, gathered, top_idx, top_val)`` — the three dense passes of the
    sparse-LBG client step (gather at LBG positions, ||g||^2, block-wise
    top-k) in a single read of g. vmap over the client axis maps onto the
    kernel's leading batch grid dimension.

    ``two_pass=None`` reads the ``REPRO_LBGM_TWO_PASS_TOPK`` env knob:
    the Mosaic-safety fallback that replaces in-kernel ``lax.top_k`` /
    ``take_along_axis`` with bisection threshold-select + one-hot-matmul
    compaction (per-row (idx, val) set equal, index-ordered)."""
    interpret = _default_interpret() if interpret is None else interpret
    two_pass = _default_two_pass() if two_pass is None else two_pass
    return _sparse_decision(bool(interpret), bool(two_pass))(blocks, idx)


def lbgm_dequant_accum(acc, w, gscale, idx, qv, scale, interpret=None):
    """Fused dequantize + scatter-accumulate of C clients' quantized
    sparse payload rows into a (nb, block) accumulator leaf (see
    ``kernels/lbgm_sparse.py``). The wire-dtype (int8/fp8) values widen
    inside the kernel — no fp32 (C, nb, kb) payload buffer. Called once
    per leaf per chunk by the engine's quantized sparse aggregator; no
    vmap routing needed (the client axis is an explicit argument)."""
    interpret = _default_interpret() if interpret is None else interpret
    return lbgm_dequant_accum_pallas(acc, w, gscale, idx, qv, scale,
                                     interpret=bool(interpret))


def flash_attention(q, k, v, *, causal=True, window=None, interpret=None):
    """GQA flash attention. q:(B,Tq,Hq,hd), k/v:(B,Tk,Hkv,hd)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, Tq, Hq, hd = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Tq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(
        B * Hq, Tk, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(
        B * Hq, Tk, hd)
    o = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                               interpret=interpret)
    return o.reshape(B, Hq, Tq, hd).transpose(0, 2, 1, 3)


def rwkv6_scan(r, k, v, logw, u, interpret=None):
    """Chunked RWKV6. r/k/v/logw: (B,T,H,hd); u: (H,hd) -> fp32 (B,T,H,hd)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, T, H, hd = r.shape
    flat = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    o = rwkv6_scan_pallas(flat(r), flat(k), flat(v), flat(logw), uf,
                          interpret=interpret)
    return o.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
