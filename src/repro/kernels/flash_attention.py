"""Flash attention (causal + optional sliding window) — TPU Pallas.

Tiling: grid (B*Hq, Tq/BQ, Tk/BK); the key axis is innermost (sequential on
TPU), carrying the online-softmax running (max, sum, acc) in fp32 VMEM
scratch. Blocks: q (BQ, hd), k/v (BK, hd) in VMEM. GQA is handled by the ops
wrapper (kv heads broadcast before the call).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, s_ref, acc_ref, *,
                  scale: float, causal: bool, window, bq: int, bk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                      # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    s_ref[...] = s_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(s_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window=None,
                           interpret=None):
    """q: (BH, Tq, hd); k, v: (BH, Tk, hd) — heads pre-flattened/broadcast.
    Returns (BH, Tq, hd) in q.dtype.

    ``interpret=None`` auto-detects the backend (compiled Mosaic on TPU,
    interpreter elsewhere), matching the ``ops.py`` wrappers."""
    if interpret is None:
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    BH, Tq, hd = q.shape
    Tk = k.shape[1]
    bq = min(BQ, Tq)
    bk = min(BK, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, Tk)
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(BH, Tq // bq, Tk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
