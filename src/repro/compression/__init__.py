"""Gradient compression baselines the paper stacks LBGM on (P3/P4)."""
from repro.compression import atomo, error_feedback, signsgd, topk  # noqa: F401


def get_compressor(name: str, **kw):
    """Returns fn: grads -> (dense compressed grads, uplink float cost)."""
    if name == "none":
        import jax.numpy as jnp
        from repro.core.tree_math import tree_size
        return lambda g: (g, jnp.asarray(float(tree_size(g)), jnp.float32))
    if name == "topk":
        k_frac = kw.get("k_frac", 0.1)
        return lambda g: topk.compress(g, k_frac)
    if name == "signsgd":
        return signsgd.compress
    if name == "atomo":
        rank = kw.get("rank", 2)
        method = kw.get("method", "svd")
        return lambda g: atomo.compress(g, rank, method)
    raise ValueError(name)


def make_uplink_pipeline(name: str = "none", kw=None,
                         use_error_feedback=None):
    """Single hook composing base compressor + error feedback.

    Returns ``(fn, uses_residual)`` where
    ``fn(grads, residual) -> (grads', residual', uplink_float_cost)``.
    The residual argument is threaded through untouched (and ignored) when
    error feedback is off, so callers can keep one static call signature.
    Default EF policy follows the paper: on iff the base compressor is top-K.
    """
    use_ef = (use_error_feedback if use_error_feedback is not None
              else name == "topk")
    use_ef = bool(use_ef) and name != "none"
    compress = get_compressor(name, **(kw or {}))
    if use_ef:
        def fn(grads, residual):
            return error_feedback.apply(compress, grads, residual)
    else:
        def fn(grads, residual):
            out, cost = compress(grads)
            return out, residual, cost
    return fn, use_ef
