"""Gradient compression baselines the paper stacks LBGM on (P3/P4)."""
from repro.compression import atomo, error_feedback, signsgd, topk  # noqa: F401


def get_compressor(name: str, **kw):
    """Returns fn: grads -> (dense compressed grads, uplink float cost)."""
    if name == "none":
        import jax.numpy as jnp
        from repro.core.tree_math import tree_size
        return lambda g: (g, jnp.asarray(float(tree_size(g)), jnp.float32))
    if name == "topk":
        k_frac = kw.get("k_frac", 0.1)
        return lambda g: topk.compress(g, k_frac)
    if name == "signsgd":
        return signsgd.compress
    if name == "atomo":
        rank = kw.get("rank", 2)
        method = kw.get("method", "svd")
        return lambda g: atomo.compress(g, rank, method)
    raise ValueError(name)
