"""Gradient compression baselines the paper stacks LBGM on (P3/P4).

Each base compressor registers a factory ``(**kw) -> (grads -> (grads',
uplink_float_cost))`` in the ``COMPRESSORS`` registry, so ``FLConfig`` /
``ExperimentSpec`` can name them by string and third-party compressors plug
in via ``@register_compressor("name")`` without touching this package.
"""
import inspect

import jax.numpy as jnp

from repro.compression import atomo, error_feedback, signsgd, topk  # noqa: F401
from repro.core.tree_math import tree_size
from repro.fed.registry import COMPRESSORS, register_compressor


@register_compressor("none")
def _identity_pipeline():
    return lambda g: (g, jnp.asarray(float(tree_size(g)), jnp.float32))


@register_compressor("topk")
def _topk_pipeline(k_frac: float = 0.1):
    return lambda g: topk.compress(g, k_frac)


@register_compressor("signsgd")
def _signsgd_pipeline():
    return signsgd.compress


@register_compressor("atomo")
def _atomo_pipeline(rank: int = 2, method: str = "svd"):
    return lambda g: atomo.compress(g, rank, method)


def get_compressor(name: str, **kw):
    """Returns fn: grads -> (dense compressed grads, uplink float cost)."""
    factory = COMPRESSORS.get(name)
    # check the kwargs bind *before* calling, so a mismatched kw dict
    # (e.g. a sweep switched fl.compressor but kept a stale compressor_kw)
    # gets an actionable error while genuine TypeErrors raised inside the
    # factory body propagate untouched
    try:
        inspect.signature(factory).bind(**kw)
    except TypeError:
        accepted = sorted(inspect.signature(factory).parameters)
        raise ValueError(
            f"compressor {name!r} does not accept kwargs {sorted(kw)}; "
            f"accepted kwargs: {accepted}") from None
    return factory(**kw)


def make_uplink_pipeline(name: str = "none", kw=None,
                         use_error_feedback=None):
    """Single hook composing base compressor + error feedback.

    Returns ``(fn, uses_residual)`` where
    ``fn(grads, residual) -> (grads', residual', uplink_float_cost)``.
    The residual argument is threaded through untouched (and ignored) when
    error feedback is off, so callers can keep one static call signature.
    Default EF policy follows the paper: on iff the base compressor is top-K.
    """
    use_ef = (use_error_feedback if use_error_feedback is not None
              else name == "topk")
    use_ef = bool(use_ef) and name != "none"
    compress = get_compressor(name, **(kw or {}))
    if use_ef:
        def fn(grads, residual):
            return error_feedback.apply(compress, grads, residual)
    else:
        def fn(grads, residual):
            out, cost = compress(grads)
            return out, residual, cost
    return fn, use_ef
