"""SignSGD with per-leaf magnitude scale (Bernstein et al. 2018; paper P4).

Uplink cost: 1 bit per element (1/32 float) + 1 scale float per leaf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(grads):
    out = {}
    bits = 0.0
    for name, g in grads.items():
        g32 = g.astype(jnp.float32)
        scale = jnp.mean(jnp.abs(g32))
        out[name] = (jnp.sign(g32) * scale).astype(g.dtype)
        bits += g.size  # 1 bit / element
    uplink_floats = jnp.asarray(bits / 32.0 + len(grads), jnp.float32)
    return out, uplink_floats
