"""Top-K gradient sparsification (paper baseline for P3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_leaf(g: jax.Array, k_frac: float):
    """Keep the k largest-|.| entries of a leaf; returns dense sparsified leaf
    and the logical uplink float count (values + indices @ ~0.5)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * k_frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    dense = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return dense.reshape(g.shape).astype(g.dtype), 1.5 * k


def compress(grads, k_frac: float):
    """Pytree top-K. Returns (sparsified dense pytree, uplink float count)."""
    total = 0.0
    out = {}
    for name, g in grads.items():
        out[name], cost = topk_leaf(g, k_frac)
        total += cost
    return out, jnp.asarray(total, jnp.float32)
