"""Error feedback (Karimireddy et al. 2019).

The paper uses EF "as standard only if top-K sparsification is used":
compress(g + e); e' = (g + e) - compressed.
"""
from __future__ import annotations

import jax

from repro.core.tree_math import tree_add, tree_sub, tree_zeros_like


def init(params_like):
    return tree_zeros_like(params_like)


def apply(compress_fn, grads, residual):
    """Returns (compressed, new_residual, uplink_cost)."""
    target = tree_add(grads, residual)
    compressed, cost = compress_fn(target)
    new_residual = tree_sub(target, compressed)
    return compressed, new_residual, cost
