"""ATOMO-style low-rank gradient factorization (Wang et al. 2018; paper P3).

Each leaf is reshaped to 2D and approximated at rank r. Two backends:
  * exact truncated SVD (small paper models, CPU-friendly)
  * subspace/power iteration (PowerSGD-flavored, MXU-only; TPU-native
    adaptation documented in DESIGN.md — ATOMO's exact SVD atoms are
    host-unfriendly at production scale)
Uplink cost: r * (m + n) floats per leaf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _to_2d(g: jax.Array):
    if g.ndim == 0:
        return g.reshape(1, 1)
    if g.ndim == 1:
        return g.reshape(1, -1)
    return g.reshape(g.shape[0], -1)


def lowrank_leaf(g: jax.Array, rank: int, method: str = "svd",
                 iters: int = 2, key=None):
    m2 = _to_2d(g).astype(jnp.float32)
    m, n = m2.shape
    r = min(rank, m, n)
    if method == "svd":
        u, s, vt = jnp.linalg.svd(m2, full_matrices=False)
        approx = (u[:, :r] * s[:r]) @ vt[:r]
    else:  # power iteration
        if key is None:
            key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (n, r), jnp.float32)
        for _ in range(iters):
            p = m2 @ q                      # (m, r)
            p, _ = jnp.linalg.qr(p)
            q = m2.T @ p                    # (n, r)
        approx = p @ q.T
    cost = r * (m + n)
    return approx.reshape(g.shape).astype(g.dtype), float(cost)


def compress(grads, rank: int = 2, method: str = "svd", key=None):
    out = {}
    total = 0.0
    for i, (name, g) in enumerate(grads.items()):
        k = None if key is None else jax.random.fold_in(key, i)
        out[name], cost = lowrank_leaf(g, rank, method, key=k)
        total += cost
    return out, jnp.asarray(total, jnp.float32)
