"""Distributed LBGM trainer (pjit) for the assigned architectures.

Clients map onto the data axes of the mesh (DESIGN.md §3):

* ``replicated`` mode — params replicated over data / sharded over model;
  per-client gradients computed with ``vmap`` over a leading client axis K
  (sharded over ("pod","data")); dense per-client LBGs (paper Algorithm 1).
* ``fsdp`` mode — params additionally sharded over data; clients processed
  sequentially with ``lax.scan`` (one resident gradient) and *top-k
  compressed* LBGs (paper P3 + App C.1) since K dense LBGs exceed HBM.

The weighted client reduction lowers to the data-axis all-reduce — the
collective IS the FL server aggregation.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import lbgm as lbgm_lib
from repro.core.tree_math import tree_size
from repro.models.transformer import init_lm, lm_loss, prefill_logits
from repro.optim.sgd import sgd_init, sgd_update
from repro.train import sharding as shd


# ------------------------------------------------------------- state

def effective_clients(cfg: ArchConfig, mesh: Mesh, global_batch: int) -> int:
    dp_total = 1
    for a in shd.dp_axes(mesh):
        dp_total *= mesh.shape[a]
    if cfg.dp_mode == "replicated":
        k = min(dp_total, global_batch)
    else:
        k = max(1, min(cfg.lbgm.num_clients, global_batch // dp_total))
    while global_batch % k:
        k -= 1
    return k


def make_loss_fn(cfg: ArchConfig):
    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch["tokens"], batch["labels"],
                       batch.get("extra"))
    return loss_fn


def init_train_state(key: jax.Array, cfg: ArchConfig, num_clients: int,
                     use_lbgm: bool = True):
    """Returns (state dict, param logical axes)."""
    params, axes = init_lm(key, cfg)
    state: Dict[str, Any] = {"params": params, "opt": sgd_init(params),
                             "step": jnp.zeros((), jnp.int32)}
    if use_lbgm and cfg.lbgm.enabled:
        if cfg.lbgm.variant == "full":
            state["lbg"] = jax.tree.map(
                lambda p: jnp.zeros((num_clients,) + p.shape, p.dtype), params)
        else:
            one = lbgm_lib.init_topk_lbg(params, cfg.lbgm.k_frac)
            state["lbg"] = jax.tree.map(
                lambda l: jnp.zeros((num_clients,) + l.shape, l.dtype), one)
    return state, axes


# ------------------------------------------------------------- steps

def _client_asg(loss_fn, params, client_batch, tau: int, lr):
    """Accumulated stochastic gradient over tau local SGD steps.

    tau == 1: plain grad (paper P4 distributed-training mode).
    tau > 1:  local SGD on per-step slices; batch leaves are (tau, b, ...).
    """
    if tau == 1:
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, client_batch)
        return g, loss

    def step(p, batch_t):
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, batch_t)
        p2 = jax.tree.map(
            lambda x, gg: (x.astype(jnp.float32)
                           - lr * gg.astype(jnp.float32)).astype(x.dtype),
            p, g)
        return p2, (g, loss)

    _, (gs, losses) = jax.lax.scan(step, params, client_batch)
    asg = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32), 0), gs)
    return asg, jnp.mean(losses)


def make_train_step(cfg: ArchConfig, num_clients: int, lr: float,
                    use_lbgm: bool = True, delta: Optional[float] = None,
                    agg_dtype=jnp.float32, sharded_step=None):
    """agg_dtype: dtype of the reconstructed-gradient aggregation payload
    (the data-axis collective). fp32 is the paper-faithful default; bf16 is
    the beyond-paper half-traffic variant (EXPERIMENTS.md §Perf)."""
    loss_fn = make_loss_fn(cfg)
    tau = cfg.lbgm.local_steps if cfg.dp_mode == "replicated" else 1
    delta = cfg.lbgm.delta_threshold if delta is None else delta
    use_lbgm = use_lbgm and cfg.lbgm.enabled
    K = num_clients

    def _client_lbgm(g, l):
        if cfg.lbgm.variant == "topk":
            return lbgm_lib.lbgm_topk_client_step(g, l, delta,
                                                  cfg.lbgm.k_frac)
        return lbgm_lib.lbgm_client_step(g, l, delta)

    def replicated_step(state, batch):
        params = state["params"]
        grads, losses = jax.vmap(
            lambda b: _client_asg(loss_fn, params, b, tau, lr))(batch)
        if use_lbgm:
            gt, new_lbg, stats = jax.vmap(_client_lbgm)(grads, state["lbg"])
        else:
            gt, new_lbg, stats = grads, None, None
        agg = jax.tree.map(
            lambda g: jnp.mean(g.astype(agg_dtype), 0).astype(jnp.float32),
            gt)
        params, opt = sgd_update(params, agg, state["opt"], lr)
        return _finish(state, params, opt, new_lbg, losses, stats)

    def fsdp_step(state, batch):
        params = state["params"]
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, xs):
            batch_k, lbg_k = xs
            g, loss = _client_asg(loss_fn, params, batch_k, 1, lr)
            if use_lbgm:
                step_fn = sharded_step or _client_lbgm
                gt, new_lbg, stats = step_fn(g, lbg_k)
            else:
                gt, new_lbg, stats = g, lbg_k, None
            acc = jax.tree.map(
                lambda a, x: a + x.astype(agg_dtype).astype(a.dtype) / K,
                acc, gt)
            return acc, (new_lbg, loss, stats)

        lbg = state["lbg"] if use_lbgm else jax.tree.map(
            lambda t: jnp.zeros((K, 1)), {"_": jnp.zeros(())})
        agg, (new_lbg, losses, stats) = jax.lax.scan(body, zero, (batch, lbg))
        params, opt = sgd_update(params, agg, state["opt"], lr)
        if not use_lbgm:
            new_lbg, stats = None, None
        return _finish(state, params, opt, new_lbg, losses, stats)

    def _finish(state, params, opt, new_lbg, losses, stats):
        new_state = dict(state)
        new_state.update(params=params, opt=opt,
                         step=state["step"] + 1)
        metrics = {"loss": jnp.mean(losses)}
        if stats is not None:
            new_state["lbg"] = new_lbg
            metrics.update(
                frac_scalar=jnp.mean(stats.sent_scalar.astype(jnp.float32)),
                mean_sin2=jnp.mean(stats.sin2),
                uplink_floats=jnp.sum(stats.uplink_floats),
                vanilla_uplink_floats=jnp.asarray(
                    float(K * tree_size(params)), jnp.float32))
        return new_state, metrics

    return replicated_step if cfg.dp_mode == "replicated" else fsdp_step


def make_prefill_step(cfg: ArchConfig):
    def step(params, batch):
        return prefill_logits(params, cfg, batch["tokens"],
                              batch.get("extra"))
    return step


# ------------------------------------------------------------- sharding glue

def train_state_shardings(state, axes, cfg: ArchConfig, mesh: Mesh,
                          embed_shard: str = "vocab"):
    mode = cfg.dp_mode
    pshard = shd.params_shardings(axes, state["params"], mode, mesh,
                                  embed_shard)
    out: Dict[str, Any] = {
        "params": pshard,
        "opt": jax.tree.map(
            lambda _: None, state["opt"]) if not state["opt"] else
        {"m": {k: pshard[k] for k in state["params"]}},
        "step": NamedSharding(mesh, P()),
    }
    if "lbg" in state:
        if cfg.lbgm.variant == "full" and mode == "replicated":
            dp = shd.dp_axes(mesh)
            out["lbg"] = {
                k: NamedSharding(mesh, P(dp, *pshard[k].spec))
                for k in state["params"]}
        else:
            model = mesh.shape.get("model", 1)

            def lbg_spec(leaf):
                # sparse LBG leaves are (K, nb, kb): shard blocks over model
                if (leaf.ndim == 3 and model > 1
                        and leaf.shape[1] % model == 0):
                    return NamedSharding(mesh, P(None, "model", None))
                return NamedSharding(mesh, P(*([None] * leaf.ndim)))
            out["lbg"] = jax.tree.map(lbg_spec, state["lbg"])
    return out


def batch_shardings(batch_spec, mesh: Mesh):
    """Leading axis (clients or batch) over ("pod","data") when divisible."""
    dp = shd.dp_axes(mesh)
    total = 1
    for a in dp:
        total *= mesh.shape[a]

    def one(s):
        lead = dp if (s.shape and s.shape[0] % total == 0) else None
        return NamedSharding(mesh, P(lead, *([None] * (len(s.shape) - 1))))
    return jax.tree.map(one, batch_spec)
