"""Logical-axis -> mesh-axis sharding rules (DESIGN.md §5)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import AbstractMesh, Mesh, NamedSharding, \
    PartitionSpec as P


def abstract_mesh(axis_sizes: Tuple[int, ...],
                  axis_names: Tuple[str, ...]) -> AbstractMesh:
    """Version-portable AbstractMesh constructor.

    jax <= 0.4.x wants one ``((name, size), ...)`` tuple; newer releases
    take ``(axis_sizes, axis_names)`` positionally. Callers always pass the
    latter form and this helper adapts.
    """
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))

# logical axes that shard over the `model` mesh axis in every mode
_MODEL_AXES = {"vocab", "heads", "kv_heads", "ff", "expert", "embed2",
               "hidden", "classes", "cout"}
# logical axes that additionally shard over `data` in fsdp mode
_FSDP_AXES = {"embed", "feat"}


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _mesh_axis_for(logical: str, mode: str, mesh: Mesh,
                   dim_size: int) -> Optional[str]:
    if logical in _MODEL_AXES and "model" in mesh.axis_names:
        if dim_size % mesh.shape["model"] == 0:
            return "model"
    if mode == "fsdp" and logical in _FSDP_AXES and "data" in mesh.axis_names:
        if dim_size % mesh.shape["data"] == 0:
            return "data"
    return None


def param_pspec(axes: Tuple[str, ...], shape: Tuple[int, ...], mode: str,
                mesh: Mesh, embed_shard: str = "vocab") -> P:
    used = set()
    out = []
    for logical, dim in zip(axes, shape):
        if embed_shard == "embed" and axes == ("vocab", "embed"):
            # hillclimb variant: shard the embedding table along d_model so
            # token gathers stay local (no per-client table all-gather);
            # the lm_head stays vocab-sharded for chunked-CE memory.
            ax = ("model" if logical == "embed"
                  and dim % mesh.shape.get("model", 1) == 0 else None)
            ax = ax if logical == "embed" else (
                "data" if mode == "fsdp" and logical == "vocab"
                and dim % mesh.shape.get("data", 1) == 0 else None)
        else:
            ax = _mesh_axis_for(logical, mode, mesh, dim)
        if ax in used:
            ax = None
        if ax is not None:
            used.add(ax)
        out.append(ax)
    return P(*out)


def params_shardings(axes_tree: Dict[str, Tuple[str, ...]],
                     params, mode: str, mesh: Mesh,
                     embed_shard: str = "vocab"):
    return {k: NamedSharding(mesh,
                             param_pspec(axes_tree[k], params[k].shape,
                                         mode, mesh,
                                         embed_shard if k == "embed"
                                         else "vocab"))
            for k in params}


def stacked_pspec(base: P, lead_axes: Tuple[str, ...]) -> P:
    """Prepend mesh axes (e.g. the client axis) to a param spec."""
    return P(lead_axes, *base)


def batch_pspec(mesh: Mesh, extra_dims: int = 2) -> P:
    """Client/batch leading axis over ("pod","data"); rest unsharded."""
    return P(dp_axes(mesh), *([None] * extra_dims))


def cache_pspec(axes: Tuple[str, ...], shape: Tuple[int, ...],
                mesh: Mesh) -> P:
    """Decode-state sharding: batch over data axes; kv_heads over model if
    divisible, else head_dim over model (distributed flash-decode)."""
    out = []
    model = mesh.shape.get("model", 1)
    # decide which dim takes the model axis (first divisible preference)
    model_target = None
    for cand in ("kv_heads", "heads", "head_dim", "head_dim2", "embed",
                 "vocab"):
        for logical, dim in zip(axes, shape):
            if logical == cand and dim % model == 0 and model > 1:
                model_target = logical
                break
        if model_target:
            break
    used_model = False
    for logical, dim in zip(axes, shape):
        if logical == "batch":
            dp = dp_axes(mesh)
            total = 1
            for a in dp:
                total *= mesh.shape[a]
            out.append(dp if dim % total == 0 and dim >= total else None)
        elif logical == model_target and not used_model:
            out.append("model")
            used_model = True
        else:
            out.append(None)
    return P(*out)


def state_shardings(axes_tree, state, mesh: Mesh):
    def one(axes, leaf):
        if not isinstance(axes, tuple):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, cache_pspec(axes, leaf.shape, mesh))
    return jax.tree.map(one, axes_tree, state,
                        is_leaf=lambda t: isinstance(t, tuple))
