"""Checkpointing: flat-dict pytrees <-> .npz (atomic, with metadata)."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "::"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}{_SEP}"))
    else:
        # exactly one trailing separator comes off — rstrip(":") would eat
        # every trailing colon and corrupt leaf keys that legitimately end
        # with one (regression-tested in tests/test_ckpt.py)
        out[prefix.removesuffix(_SEP)] = tree
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict) and node and all(
                k.startswith("#") for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node
    return fix(tree)


def save_checkpoint(path: str, state, metadata: Optional[dict] = None):
    flat = _flatten(jax.tree.map(np.asarray, state))
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # suffix must end in .npz or np.savez silently writes to "<tmp>.npz"
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, __metadata__=json.dumps(metadata or {}), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_checkpoint(path: str) -> Tuple[Any, dict]:
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__metadata__"]))
        flat = {k: z[k] for k in z.files if k != "__metadata__"}
    return _unflatten(flat), meta
