"""End-to-end training driver.

Runs the LBGM distributed trainer on real (synthetic-markov) data on whatever
devices exist — CPU debug mesh by default, production mesh shapes via
--mesh. Checkpoints + metrics under --out.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 100 --seq 256 --batch 8 --clients 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import markov_lm
from repro.launch.mesh import make_debug_mesh
from repro.models.frontends import make_stub_embeds
from repro.train import trainer as tr


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer reduced variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8, help="per-client batch")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--delta", type=float, default=None,
                    help="LBGM sin^2 threshold (default: config)")
    ap.add_argument("--no-lbgm", action="store_true")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M model)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--pool", type=int, default=8,
                    help="batches of local data per client (small pool = "
                         "paper-like FL regime with recurring local epochs)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/train")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    over = {}
    if args.d_model:
        n_kv = max(2, args.d_model // 128)
        n_q = max(n_kv, (args.d_model // 64) // n_kv * n_kv)  # divisible GQA
        over.update(d_model=args.d_model, n_heads=n_q, head_dim=64,
                    n_kv_heads=n_kv, d_ff=args.d_model * 3)
    if args.layers:
        over["n_layers"] = args.layers
    if args.vocab:
        over["vocab_size"] = args.vocab
    if over:
        cfg = dataclasses.replace(cfg, **over)
    cfg = dataclasses.replace(cfg, dp_mode="replicated")

    key = jax.random.PRNGKey(args.seed)
    K = args.clients
    state, axes = tr.init_train_state(key, cfg, K,
                                      use_lbgm=not args.no_lbgm)
    n_params = sum(v.size for v in state["params"].values())
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M clients={K} "
          f"lbgm={'off' if args.no_lbgm else cfg.lbgm.variant}")

    step_fn = jax.jit(tr.make_train_step(cfg, K, args.lr,
                                         use_lbgm=not args.no_lbgm,
                                         delta=args.delta))

    # markov-chain LM stream, partitioned iid across clients
    toks, labels = markov_lm(K * args.batch * args.pool, args.seq,
                             cfg.vocab_size, seed=args.seed)
    toks = toks.reshape(K, -1, args.seq)
    labels = labels.reshape(K, -1, args.seq)
    rng = np.random.RandomState(args.seed)
    extra = make_stub_embeds(key, cfg, args.batch)

    os.makedirs(args.out, exist_ok=True)
    history = []
    t0 = time.time()
    uplink = vanilla = 0.0
    for step in range(args.steps):
        idx = rng.randint(0, toks.shape[1], size=(K, args.batch))
        batch = {
            "tokens": jnp.asarray(np.take_along_axis(
                toks, idx[..., None], axis=1)),
            "labels": jnp.asarray(np.take_along_axis(
                labels, idx[..., None], axis=1)),
        }
        if extra is not None:
            batch["extra"] = jnp.broadcast_to(
                extra[None], (K,) + extra.shape)
        state, m = step_fn(state, batch)
        m = {k: float(v) for k, v in m.items()}
        uplink += m.get("uplink_floats", 0.0)
        vanilla += m.get("vanilla_uplink_floats", 0.0)
        m["step"] = step
        history.append(m)
        if (step + 1) % args.log_every == 0:
            sav = 1 - uplink / vanilla if vanilla else 0.0
            print(f"step {step+1:5d} loss={m['loss']:.4f} "
                  f"scalar_frac={m.get('frac_scalar', 0):.2f} "
                  f"cum_savings={sav:.1%} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)

    save_checkpoint(os.path.join(args.out, "final.npz"),
                    {"params": state["params"]},
                    {"arch": cfg.name, "steps": args.steps})
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump(history, f)
    print("done:", args.out)
    return history


if __name__ == "__main__":
    main()
