"""Abstract (ShapeDtypeStruct) inputs for every (arch x shape) pair —
weak-type-correct, shardable, zero device allocation."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.frontends import extra_embed_shape
from repro.models.transformer import init_lm
from repro.serve.decode import init_decode_state
from repro.train.trainer import effective_clients, init_train_state


def abstract_params(cfg: ArchConfig):
    """(params SDS pytree, logical axes) without allocating."""
    holder = {}

    def f(key):
        p, axes = init_lm(key, cfg)
        holder["axes"] = axes
        return p

    sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    return sds, holder["axes"]


def abstract_train_state(cfg: ArchConfig, num_clients: int,
                         use_lbgm: bool = True):
    holder = {}

    def f(key):
        st, axes = init_train_state(key, cfg, num_clients, use_lbgm)
        holder["axes"] = axes
        return st

    sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    return sds, holder["axes"]


def abstract_decode_state(cfg: ArchConfig, batch: int, seq_len: int):
    holder = {}

    def f():
        st, axes = init_decode_state(cfg, batch, seq_len)
        holder["axes"] = axes
        return st

    sds = jax.eval_shape(f)
    return sds, holder["axes"]


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                      num_clients: int) -> Dict[str, Any]:
    K = num_clients
    b = shape.global_batch // K
    T = shape.seq_len
    tau = cfg.lbgm.local_steps if cfg.dp_mode == "replicated" else 1
    lead: Tuple[int, ...] = (K, tau, b) if tau > 1 else (K, b)
    specs = {
        "tokens": jax.ShapeDtypeStruct(lead + (T,), jnp.int32),
        "labels": jax.ShapeDtypeStruct(lead + (T,), jnp.int32),
    }
    es = extra_embed_shape(cfg, b)
    if es is not None:
        specs["extra"] = jax.ShapeDtypeStruct(lead + es[1:],
                                              jnp.dtype(cfg.dtype))
    return specs


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}
    es = extra_embed_shape(cfg, B)
    if es is not None:
        specs["extra"] = jax.ShapeDtypeStruct(es, jnp.dtype(cfg.dtype))
    return specs


def decode_token_spec(shape: ShapeConfig):
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
