"""Production mesh construction (function, never module-level — importing
this module must not touch jax device state)."""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh

#: JSON-able FL mesh spec (see ``repro.fed.flconfig.FLConfig.mesh``):
#: None = all local devices on the client axis, int n = (n, 1),
#: (c, m) = c-way client mesh x m-way model mesh.
MeshSpec = Union[None, int, Sequence[int]]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests/examples)."""
    devices = jax.devices()[: data * model]
    return Mesh(np.asarray(devices).reshape(data, model), ("data", "model"))


def make_fl_mesh(spec: MeshSpec = None, *, client_axis: str = "clients",
                 model_axis: str = "model") -> Mesh:
    """Named 2-D ``(clients, model)`` mesh for FL rounds
    (``scheduler="sharded"``) — the resolver behind ``FLConfig.mesh``.

    The config stores a plain JSON value; this turns it into a live Mesh:

    * ``None``   — every local device on the client axis: ``(n_local, 1)``;
    * ``int n``  — ``(n, 1)``: pure client-data-parallelism, the pre-2-D
      spelling (bit-for-bit identical rounds);
    * ``(c, m)`` — ``c``-way client mesh x ``m``-way model-axis sharding of
      the LBG decision/banks.

    The mesh is always physically 2-D (the model axis has extent 1 in the
    first two cases) so every consumer — shard_map specs, NamedSharding
    bank placement, psum axes — speaks one mesh vocabulary.
    """
    devices = jax.devices()
    if spec is None:
        shape = (len(devices), 1)
    elif isinstance(spec, int):
        shape = (spec, 1)
    else:
        spec = tuple(int(d) for d in spec)
        if len(spec) != 2:
            raise ValueError(
                f"FL mesh spec must be None, an int, or a (clients, model) "
                f"pair, got {spec!r}")
        shape = spec
    if min(shape) < 1:
        raise ValueError(f"FL mesh needs >= 1 device per axis, got {shape}")
    n = shape[0] * shape[1]
    if n > len(devices):
        raise RuntimeError(
            f"need {n} devices for the {shape} (clients, model) FL mesh, "
            f"have {len(devices)}; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return Mesh(np.asarray(devices[:n]).reshape(shape),
                (client_axis, model_axis))


def make_client_mesh(num_devices: Optional[int] = None,
                     axis: str = "clients") -> Mesh:
    """1-D client mesh — pre-2-D spelling, kept for external callers.

    New code (and the engine) goes through :func:`make_fl_mesh`, which
    returns the same devices as a ``(n, 1)`` named 2-D mesh.
    """
    devices = jax.devices()
    n = len(devices) if num_devices is None else num_devices
    if n < 1:
        raise ValueError(f"client mesh needs >= 1 device, got {n}")
    if n > len(devices):
        raise RuntimeError(
            f"need {n} devices for the client mesh, have {len(devices)}; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return Mesh(np.asarray(devices[:n]), (axis,))
