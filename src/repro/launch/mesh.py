"""Production mesh construction (function, never module-level — importing
this module must not touch jax device state)."""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests/examples)."""
    devices = jax.devices()[: data * model]
    return Mesh(np.asarray(devices).reshape(data, model), ("data", "model"))


def make_client_mesh(num_devices: Optional[int] = None,
                     axis: str = "clients") -> Mesh:
    """1-D mesh for client-data-parallel FL rounds (``scheduler="sharded"``).

    ``num_devices=None`` takes every local device; an explicit count must
    not exceed what this process can see. This is the resolver behind
    ``FLConfig.mesh`` — the config stores the device count (plain JSON-able
    int), the scheduler turns it into a live Mesh here.
    """
    devices = jax.devices()
    n = len(devices) if num_devices is None else num_devices
    if n < 1:
        raise ValueError(f"client mesh needs >= 1 device, got {n}")
    if n > len(devices):
        raise RuntimeError(
            f"need {n} devices for the client mesh, have {len(devices)}; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return Mesh(np.asarray(devices[:n]), (axis,))
