"""Serving driver: batched greedy decode with the KV-cache serve_step.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.frontends import make_stub_embeds
from repro.models.transformer import init_lm
from repro.serve.decode import init_decode_state, serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params, _ = init_lm(key, cfg)
    state, _ = init_decode_state(cfg, args.batch, args.cache_len)
    if cfg.encdec:
        state["enc_out"] = make_stub_embeds(key, cfg, args.batch)

    step = jax.jit(lambda p, s, t: serve_step(p, cfg, s, t),
                   donate_argnums=(1,))
    rng = np.random.RandomState(args.seed)
    prompt = rng.randint(0, cfg.vocab_size,
                         size=(args.batch, args.prompt_len)).astype(np.int32)

    # prefill via repeated decode (exercises the ring cache end to end)
    tok = jnp.asarray(prompt[:, :1])
    for t in range(args.prompt_len):
        logits, state = step(params, state, jnp.asarray(prompt[:, t:t + 1]))
    out = []
    t0 = time.time()
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(args.gen):
        out.append(np.asarray(tok))
        logits, state = step(params, state, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print("generated tokens:\n", gen)
    print(f"{args.gen} steps x batch {args.batch}: "
          f"{1e3 * dt / args.gen:.1f} ms/step, "
          f"{args.batch * args.gen / dt:.1f} tok/s")
    return gen


if __name__ == "__main__":
    main()
