import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x input-shape) on the
production meshes; record memory/cost analyses + roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis import roofline as rl                   # noqa: E402
from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES,    # noqa: E402
                           active_param_count, get_config)
from repro.launch import specs as sp                        # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models.transformer import prefill_logits         # noqa: E402
from repro.serve.decode import serve_step                   # noqa: E402
from repro.train import sharding as shd                     # noqa: E402
from repro.train import trainer as tr                       # noqa: E402


def should_skip(cfg, shape_cfg):
    if shape_cfg.name == "long_500k" and cfg.long_context == "skip":
        return (f"{cfg.name}: long_500k skipped — enc-dec decoder context "
                "architecturally capped (DESIGN.md §4)")
    return None


def lower_pair(arch: str, shape_name: str, mesh, mesh_name: str,
               use_lbgm: bool = True, lr: float = 1e-2,
               unroll: bool = False, cfg_override=None,
               agg_dtype=None, embed_shard: str = "vocab",
               clients_override=None, sharded_lbgm: bool = False):
    import dataclasses
    import jax.numpy as jnp
    cfg = cfg_override or get_config(arch)
    if unroll:
        cfg = dataclasses.replace(cfg, unroll=True)
    agg_dtype = agg_dtype or jnp.float32
    shape_cfg = INPUT_SHAPES[shape_name]
    skip = should_skip(cfg, shape_cfg)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": skip}

    t0 = time.time()
    if shape_cfg.kind == "train":
        K = tr.effective_clients(cfg, mesh, shape_cfg.global_batch)
        if unroll and cfg.dp_mode == "fsdp":
            # the scan over clients is also cost-undercounted; one client
            # with the full global batch has identical total model FLOPs
            K = 1
        if clients_override:
            K = clients_override
        state_sds, axes = sp.abstract_train_state(cfg, K, use_lbgm)
        batch_sds = sp.train_batch_specs(cfg, shape_cfg, K)
        state_sh = tr.train_state_shardings(state_sds, axes, cfg, mesh,
                                            embed_shard)
        batch_sh = tr.batch_shardings(batch_sds, mesh)
        sharded_step = None
        if sharded_lbgm and use_lbgm and cfg.lbgm.variant == "topk":
            import jax.numpy as jnp2
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import lbgm_sharded as ls
            gspecs = {k: sh.spec for k, sh in state_sh["params"].items()}
            lbg_sds, lbg_sh = ls.sharded_lbg_layout(
                state_sds["params"], gspecs, mesh, cfg.lbgm.k_frac)
            # leading client axis on the stored state
            state_sds["lbg"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype),
                lbg_sds, is_leaf=lambda x: isinstance(x,
                                                      jax.ShapeDtypeStruct))
            state_sh["lbg"] = jax.tree.map(
                lambda sh_: NamedSharding(mesh, P(None, *sh_.spec)), lbg_sh)
            sharded_step = ls.make_sharded_topk_step(
                cfg, mesh, gspecs, cfg.lbgm.delta_threshold)
        step = tr.make_train_step(cfg, K, lr, use_lbgm=use_lbgm,
                                  agg_dtype=agg_dtype,
                                  sharded_step=sharded_step)
        with mesh:
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              donate_argnums=(0,)).lower(state_sds, batch_sds)
    elif shape_cfg.kind == "prefill":
        params_sds, axes = sp.abstract_params(cfg)
        psh = shd.params_shardings(axes, params_sds, cfg.dp_mode, mesh)
        batch_sds = sp.prefill_batch_specs(cfg, shape_cfg)
        batch_sh = tr.batch_shardings(batch_sds, mesh)
        fn = lambda p, b: prefill_logits(p, cfg, b["tokens"], b.get("extra"))
        with mesh:
            lowered = jax.jit(fn, in_shardings=(psh, batch_sh)).lower(
                params_sds, batch_sds)
    else:  # decode
        params_sds, axes = sp.abstract_params(cfg)
        psh = shd.params_shardings(axes, params_sds, cfg.dp_mode, mesh)
        state_sds, st_axes = sp.abstract_decode_state(
            cfg, shape_cfg.global_batch, shape_cfg.seq_len)
        st_sh = shd.state_shardings(st_axes, state_sds, mesh)
        tok_sds = sp.decode_token_spec(shape_cfg)
        tok_sh = tr.batch_shardings(tok_sds, mesh)
        fn = lambda p, s, t: serve_step(p, cfg, s, t)
        with mesh:
            lowered = jax.jit(fn, in_shardings=(psh, st_sh, tok_sh),
                              donate_argnums=(1,)).lower(
                params_sds, state_sds, tok_sds)

    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            mem[attr] = int(getattr(ma, attr, 0) or 0)
        print(ma)
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)
    try:
        cost_list = compiled.cost_analysis()
        cost = cost_list[0] if isinstance(cost_list, list) else cost_list
        print({k: cost[k] for k in ("flops", "bytes accessed")
               if k in cost})
    except Exception as e:  # pragma: no cover
        cost = {}
        print("cost_analysis failed:", e)

    hlo = compiled.as_text()
    chips = mesh.devices.size
    mf = rl.model_flops(cfg, shape_cfg, active_param_count(cfg))
    report = rl.build_report(arch, shape_name, mesh_name, chips,
                             dict(cost) if cost else {}, hlo, mf)
    coll = rl.collective_bytes(hlo)
    row = report.row()
    row.update(status="ok", compile_s=t_compile, memory=mem,
               collectives={k: v for k, v in coll.items()},
               hbm_per_device_gb=(mem.get("argument_size_in_bytes", 0)
                                  + mem.get("temp_size_in_bytes", 0)
                                  + mem.get("output_size_in_bytes", 0)) / 2**30)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-lbgm", action="store_true",
                    help="vanilla-FL baseline step (no LBGM state)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll scans for accurate cost analysis "
                         "(roofline pass; scan run stays the memory proof)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [(False, "pod16x16"), (True, "pod2x16x16")]
    else:
        mp = args.multi_pod
        meshes = [(mp, "pod2x16x16" if mp else "pod16x16")]

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod, mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            for shape in shapes:
                tag = f"{mesh_name}/{arch}__{shape}"
                print(f"=== {tag} ===", flush=True)
                try:
                    row = lower_pair(arch, shape, mesh, mesh_name,
                                     use_lbgm=not args.no_lbgm,
                                     unroll=args.unroll)
                except Exception:
                    traceback.print_exc()
                    row = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "FAILED",
                           "error": traceback.format_exc(limit=4)}
                    failures.append(tag)
                d = os.path.join(args.out, mesh_name)
                os.makedirs(d, exist_ok=True)
                suffix = "__vanilla" if args.no_lbgm else ""
                suffix += "__unroll" if args.unroll else ""
                with open(os.path.join(d, f"{arch}__{shape}{suffix}.json"),
                          "w") as f:
                    json.dump(row, f, indent=1, default=str)
                if row["status"] == "ok":
                    print(f"  ok compile={row['compile_s']:.1f}s "
                          f"dominant={row['dominant']} "
                          f"terms=({row['compute_s']:.4f}, "
                          f"{row['memory_s']:.4f}, "
                          f"{row['collective_s']:.4f})s "
                          f"useful={row['useful_flops_ratio']:.3f}",
                          flush=True)
                elif row["status"] == "skipped":
                    print("  skipped:", row["reason"], flush=True)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete: all pairs lowered + compiled")


if __name__ == "__main__":
    main()
