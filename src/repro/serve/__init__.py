from repro.serve.decode import init_decode_state, serve_step  # noqa: F401
