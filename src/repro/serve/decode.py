"""Serving: single-token decode with a KV cache of ``cache_len``.

Cache layouts per block kind:
  attn  — full ring cache of length seq_len (keys stored post-RoPE)
  swa   — ring cache of length min(window, seq_len)  (sub-quadratic path)
  rwkv6 — recurrent state (B, H, hd, hd) + last token embed (O(1)/token)
  rglru — hidden state (B, d) + conv tail (B, 3, d)     (O(1)/token)

``long_500k`` policy (DESIGN.md §4): dense archs decode through their "swa"
variant; ssm/hybrid decode through recurrent state; whisper skips.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv6_lib
from repro.models.attention import (decode_attention, mrope_rotate,
                                    rope_rotate)
from repro.models.common import rms_norm, subtree
from repro.models.transformer import uses_scan


def _cache_len(cfg: ArchConfig, kind: str, seq_len: int,
               force_window: bool) -> int:
    if kind == "swa" or (force_window and kind == "attn"):
        return min(cfg.sliding_window, seq_len)
    return seq_len


def _block_cache(cfg: ArchConfig, kind: str, B: int, L: int):
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    if kind in ("attn", "swa"):
        shape = (B, L, cfg.n_kv_heads, hd)
        axes = ("batch", "cache", "kv_heads", "head_dim")
        return ({"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)},
                {"k": axes, "v": axes})
    if kind == "rwkv6":
        return ({"s": jnp.zeros((B, cfg.n_heads, hd, hd), jnp.float32),
                 "last": jnp.zeros((B, cfg.d_model), dt)},
                {"s": ("batch", "heads", "head_dim", "head_dim2"),
                 "last": ("batch", "embed")})
    if kind == "rglru":
        return ({"h": jnp.zeros((B, cfg.d_model), jnp.float32),
                 "conv": jnp.zeros((B, rglru_lib.CONV_W - 1, cfg.d_model),
                                   dt)},
                {"h": ("batch", "embed"), "conv": ("batch", "conv", "embed")})
    raise ValueError(kind)


def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int,
                      use_window: Optional[bool] = None):
    """Returns (state pytree, logical-axes pytree).

    ``use_window``: force the sliding-window cache for "attn" blocks
    (the sub-quadratic long-context path). Defaults on for long contexts
    per cfg.long_context.
    """
    if use_window is None:
        use_window = cfg.long_context == "swa" and seq_len > 65536
    state: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    axes: Dict[str, Any] = {"pos": ()}
    if cfg.encdec:
        state["enc_out"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
        axes["enc_out"] = ("batch", "enc_seq", "embed")
    if uses_scan(cfg):
        kind = cfg.block_pattern[0]
        L = _cache_len(cfg, kind, seq_len, use_window)
        c, a = _block_cache(cfg, kind, batch, L)
        state["layers"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), c)
        axes["layers"] = jax.tree.map(lambda t: ("layers",) + t, a,
                                      is_leaf=lambda t: isinstance(t, tuple))
    else:
        for i in range(cfg.n_layers):
            kind = cfg.block_kind(i) if not cfg.encdec else "attn"
            L = _cache_len(cfg, kind, seq_len, use_window)
            c, a = _block_cache(cfg, kind, batch, L)
            state[f"layer_{i:02d}"] = c
            axes[f"layer_{i:02d}"] = a
    return state, axes


def _decode_attn(p, x1, cfg: ArchConfig, cache, pos, kind):
    """x1 (B,1,d); ring-buffer kv cache update + attention over cache."""
    B = x1.shape[0]
    hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    L = cache["k"].shape[1]
    q = jnp.einsum("btd,dh->bth", x1, p["wa_q"]).reshape(B, 1, nq, hd)
    k = jnp.einsum("btd,dh->bth", x1, p["wa_k"]).reshape(B, 1, nkv, hd)
    v = jnp.einsum("btd,dh->bth", x1, p["wa_v"]).reshape(B, 1, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    posb = jnp.broadcast_to(pos[None, None], (B, 1))
    if cfg.mrope:
        # after the vision prefix, all three position streams advance together
        pos3 = jnp.broadcast_to(pos[None, None, None], (3, B, 1))
        q = mrope_rotate(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = mrope_rotate(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = rope_rotate(q, posb, cfg.rope_theta)
        k = rope_rotate(k, posb, cfg.rope_theta)
    slot = jnp.mod(pos, L)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    o = decode_attention(q, k_cache, v_cache,
                         valid_len=jnp.minimum(pos + 1, L))
    o = jnp.einsum("bth,hd->btd", o.reshape(B, 1, nq * hd), p["wa_o"])
    return o, {"k": k_cache, "v": v_cache}


def _decode_cross_attn(p, x1, enc_out, cfg: ArchConfig):
    B = x1.shape[0]
    Te = enc_out.shape[1]
    hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("btd,dh->bth", x1, p["wx_q"]).reshape(B, 1, nq, hd)
    k = jnp.einsum("btd,dh->bth", enc_out, p["wx_k"]).reshape(B, Te, nkv, hd)
    v = jnp.einsum("btd,dh->bth", enc_out, p["wx_v"]).reshape(B, Te, nkv, hd)
    o = decode_attention(q, k, v, valid_len=Te)
    return jnp.einsum("bth,hd->btd", o.reshape(B, 1, nq * hd), p["wx_o"])


def _decode_ffn(p, x1, cfg: ArchConfig):
    from repro.models.transformer import _apply_ffn
    out, _ = _apply_ffn(p, x1, cfg)
    return out


def _decode_block(p, x1, cfg: ArchConfig, kind, cache, pos, enc_out=None):
    h = rms_norm(x1, p["norm1"], cfg.norm_eps)
    if kind in ("attn", "swa"):
        h, cache = _decode_attn(p, h, cfg, cache, pos, kind)
    elif kind == "rwkv6":
        h, (s, last) = rwkv6_lib.rwkv6_decode_step(
            subtree(p, "tmix"), h, cfg, cache["s"], cache["last"])
        cache = {"s": s, "last": last}
    elif kind == "rglru":
        h, (hs, conv) = rglru_lib.rglru_decode_step(
            subtree(p, "rec"), h, cfg, cache["h"], cache["conv"])
        cache = {"h": hs, "conv": conv}
    x1 = x1 + h
    if enc_out is not None:
        hx = rms_norm(x1, p["norm_x"], cfg.norm_eps)
        x1 = x1 + _decode_cross_attn(p, hx, enc_out, cfg)
    h2 = rms_norm(x1, p["norm2"], cfg.norm_eps)
    return x1 + _decode_ffn(p, h2, cfg), cache


def serve_step(params, cfg: ArchConfig, state, token: jax.Array):
    """One decode step. token (B, 1) int32 -> (logits (B,1,V), new state)."""
    B = token.shape[0]
    pos = state["pos"]
    x = jnp.take(params["embed"], token, axis=0)
    new_state = dict(state)

    if cfg.encdec:
        enc_out = state["enc_out"]
        for i in range(cfg.n_layers):
            x, c = _decode_block(subtree(params, f"dec_{i:02d}"), x, cfg,
                                 "attn", state[f"layer_{i:02d}"], pos,
                                 enc_out=enc_out)
            new_state[f"layer_{i:02d}"] = c
    elif uses_scan(cfg):
        kind = cfg.block_pattern[0]
        stacked_p = subtree(params, "blocks")

        def body(carry, xs):
            h = carry
            layer_p, layer_c = xs
            h, c = _decode_block(layer_p, h, cfg, kind, layer_c, pos)
            return h, c

        x, new_caches = jax.lax.scan(body, x, (stacked_p, state["layers"]))
        new_state["layers"] = new_caches
    else:
        for i in range(cfg.n_layers):
            kind = cfg.block_kind(i)
            x, c = _decode_block(subtree(params, f"layer_{i:02d}"), x, cfg,
                                 kind, state[f"layer_{i:02d}"], pos)
            new_state[f"layer_{i:02d}"] = c

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head)
    new_state["pos"] = pos + 1
    return logits, new_state
