"""Adam (fp32 moments) — framework option beyond the paper's SGD."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_init(params):
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, opt_state, lr, b1=0.9, b2=0.999, eps=1e-8,
                weight_decay: float = 0.0):
    t = opt_state["t"] + 1
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                     opt_state["m"], grads)
    v = jax.tree.map(
        lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        opt_state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, mm, vv):
        step = lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}
