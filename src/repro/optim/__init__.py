from repro.optim.sgd import sgd_init, sgd_update  # noqa: F401
from repro.optim.adam import adam_init, adam_update  # noqa: F401
from repro.optim.schedules import constant, cosine, make_schedule  # noqa: F401
