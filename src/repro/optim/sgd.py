"""SGD (+ optional momentum). The paper trains with plain SGD."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return {}
    return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                              params)}


def sgd_update(params, grads, opt_state, lr, momentum: float = 0.0,
               weight_decay: float = 0.0):
    if momentum == 0.0:
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * (g.astype(jnp.float32)
                                  + weight_decay * p.astype(jnp.float32))
                          ).astype(p.dtype),
            params, grads)
        return new_params, opt_state
    m = jax.tree.map(
        lambda mm, g: momentum * mm + g.astype(jnp.float32),
        opt_state["m"], grads)
    new_params = jax.tree.map(
        lambda p, mm: (p.astype(jnp.float32) - lr * mm).astype(p.dtype),
        params, m)
    return new_params, {"m": m}
