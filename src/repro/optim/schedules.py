"""LR schedules, including the Corollary-1 rate eta = 1/sqrt(tau*T)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, warmup: int = 0, floor: float = 0.0):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * jnp.where(step < warmup, warm, cos)
    return f


def corollary1(tau: int, total_rounds: int):
    """eta = 1/sqrt(tau*T) (paper Corollary 1)."""
    eta = 1.0 / (tau * total_rounds) ** 0.5
    return constant(eta)


def make_schedule(name: str, lr: float, total_steps: int = 1000, **kw):
    if name == "constant":
        return constant(lr)
    if name == "cosine":
        return cosine(lr, total_steps, **kw)
    if name == "corollary1":
        return corollary1(kw.get("tau", 1), total_steps)
    raise ValueError(name)
