from repro.core.lbgm import (LBGMStats, corollary1_threshold,  # noqa: F401
                             init_topk_lbg, lbgm_client_step, lbgm_stats,
                             lbgm_topk_client_step)
