"""Pytree vector math used by LBGM (fp32 accumulation throughout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_vdot(a, b) -> jax.Array:
    """<a, b> over all leaves, fp32 accumulate.

    Deliberately sum(x*y) rather than jnp.vdot: vdot RESHAPES to 1-D, and
    flattening a model-sharded leaf makes GSPMD all-gather the whole fp32
    leaf (measured 36 GiB/step on qwen3 train — EXPERIMENTS.md §Perf);
    the elementwise form keeps the sharding and reduces to a scalar psum.
    """
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float32)
                                          * y.astype(jnp.float32)), a, b))
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.zeros((), jnp.float32)


def tree_sq_norm(a) -> jax.Array:
    return tree_vdot(a, a)


def tree_scale(a, s):
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), a)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_select(pred, a, b):
    """Per-leaf jnp.where(pred, a, b) with a scalar bool predicate."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_zeros_like(a, dtype=None):
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), a)


def tree_size(a) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)
