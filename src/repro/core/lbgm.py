"""Look-back Gradient Multiplier (paper Algorithm 1) — the core contribution.

Per client k and round t, with accumulated stochastic gradient g and stored
look-back gradient (LBG) l:

    sin^2(alpha) = 1 - (<g,l> / (||g|| ||l||))^2          (LBP error, step 6)
    rho          = <g,l> / ||l||^2                        (LBC, step 8)
    if sin^2(alpha) <= delta:  upload the SCALAR rho; server uses rho*l
    else:                      upload g; both sides set l <- g

Two LBG storage variants:
  * ``full`` — dense LBG pytree (paper-faithful Algorithm 1).
  * ``topk`` — LBG kept as per-leaf (indices, values): LBGM stacked on top-K
    (paper §P3 plug-and-play + App. C.1 "LBG compression"), used for the
    >=34B assigned archs where K dense LBGs exceed pod HBM (DESIGN.md §3).
    Projection statistics use the *dense* current gradient against the
    sparse LBG (a tighter estimate than sparse-sparse, and a cheap gather);
    full-round uploads transmit top-K(g) and refresh the sparse LBG.

All decisions are ``jnp.where``-based (no data-dependent control flow) so
the aggregation program stays static for pjit/TPU.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.tree_math import (tree_scale, tree_select, tree_sq_norm,
                                  tree_vdot, tree_size)

EPS = 1e-20


class LBGMStats(NamedTuple):
    sin2: jax.Array          # LBP error
    rho: jax.Array           # LBC
    sent_scalar: jax.Array   # bool: True => only 1 float on the uplink
    uplink_floats: jax.Array # logical floats uploaded this round
    grad_sq_norm: jax.Array


def recycle_gate(sin2, delta_threshold) -> jax.Array:
    """Algorithm 1 step 7: recycle iff the LBP error clears the threshold.

    ``sin2 == 1.0`` covers both degenerate LBGs (round 0) and orthogonal
    gradients — either way a full round is strictly better. The single
    home of the gate: every decomposition (dense, sparse, client- and
    model-sharded) routes through here, so the rule cannot drift.
    """
    return (sin2 <= delta_threshold) & (sin2 < 1.0)


def decision_from_scalars(gl, gg, ll, delta_threshold
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(sin2, rho, sent_scalar) from the three projection scalars.

    The whole Algorithm-1 decision once <g,l>, ||g||^2, ||l||^2 are in
    hand — how the scalars were reduced (dense vdots, sparse gathers, a
    psum over mesh axes) is the only thing the call sites differ in.
    """
    cos2 = (gl * gl) / jnp.maximum(gg * ll, EPS)
    sin2 = jnp.where(ll > EPS, 1.0 - cos2, 1.0)
    rho = gl / jnp.maximum(ll, EPS)
    return sin2, rho, recycle_gate(sin2, delta_threshold)


def topk_uplink_stats(sin2, rho, scalar, gg, total_k: int) -> LBGMStats:
    """Sparse-store round stats incl. the uplink cost model (k values +
    k block-local indices ~ 1.5 floats per kept value on a full round,
    exactly 1 float on a recycle round) — shared by every topk-step
    decomposition so the accounting stays mesh- and variant-independent."""
    return LBGMStats(sin2=sin2, rho=rho, sent_scalar=scalar,
                     uplink_floats=jnp.where(scalar, 1.0, 1.5 * total_k),
                     grad_sq_norm=gg)


def lbgm_stats(grad, lbg, fused: bool = False
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(sin2, rho, gg). Degenerate LBG (zero) forces a full-gradient round.

    ``fused=True`` computes the three O(M) reductions (<g,l>, ||g||^2,
    ||l||^2) with the one-pass Pallas projection kernel
    (``kernels.ops.lbgm_projection``; batched over the client axis under
    ``vmap``) instead of three separate XLA passes — numerically equal
    within fp32 reassociation tolerance.
    """
    if fused:
        from repro.kernels.ops import lbgm_projection
        gl, gg, ll = lbgm_projection(grad, lbg)
    else:
        gl = tree_vdot(grad, lbg)
        gg = tree_sq_norm(grad)
        ll = tree_sq_norm(lbg)
    sin2, rho, _ = decision_from_scalars(gl, gg, ll, 1.0)
    return sin2, rho, gg


def lbgm_client_step(grad, lbg, delta_threshold, fused: bool = False):
    """Paper Algorithm 1, worker side (variant='full').

    Returns (g_tilde as seen by the server, new_lbg, LBGMStats).
    ``fused`` routes the projection statistics through the one-pass Pallas
    kernel (see :func:`lbgm_stats`).
    """
    sin2, rho, gg = lbgm_stats(grad, lbg, fused=fused)
    scalar = recycle_gate(sin2, delta_threshold)
    g_tilde = tree_select(scalar, tree_scale(lbg, rho), grad)
    new_lbg = tree_select(scalar, lbg, grad)
    m = tree_size(grad)
    stats = LBGMStats(sin2=sin2, rho=rho, sent_scalar=scalar,
                      uplink_floats=jnp.where(scalar, 1.0, float(m)),
                      grad_sq_norm=gg)
    return g_tilde, new_lbg, stats


# ------------------------------------------------------------- topk variant

BLOCK = 65536


def _block_layout(size: int, k_frac: float) -> Tuple[int, int, int]:
    """(nb, block, kb) for a leaf of `size`.

    Block-wise top-k (top-kb per contiguous block) instead of a global sort:
    (i) a full-vector sort would force XLA to all-gather multi-GB operands on
    a sharded mesh; (ii) block-LOCAL indices stay within int32 even for
    >2^31-element leaves (stacked 88-layer FFN grads). nb is rounded up to a
    multiple of 16 so the sparse LBG can shard over the model axis.
    """
    block = min(size, BLOCK)
    nb = -(-size // block)
    if nb > 1:
        nb = -(-nb // 16) * 16
    k = max(1, int(size * k_frac))
    kb = max(1, min(block, k // nb if nb > 1 else k))
    return nb, block, kb


def _to_blocks(g: jax.Array, nb: int, block: int) -> jax.Array:
    flat = g.reshape(-1).astype(jnp.float32)
    pad = nb * block - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(nb, block)


def leaf_topk(g: jax.Array, k_frac: float, trim_pad: bool = False):
    """Block-wise top-|.|: returns ({'idx': (nb,kb) block-local int32,
    'val': (nb,kb) f32}).

    ``trim_pad=True`` (the engine's fused/sparse hot path): ``_block_layout``
    rounds nb up to a multiple of 16 for model-axis sharding, so rows past
    the data are entirely zero padding; top_k on an all-zero row is exactly
    (iota, zeros) (ties keep the lower index), so those rows are emitted
    directly instead of paying the selection — the decision's dominant cost
    on multi-block leaves. Bit-identical values; ``False`` keeps the
    original full-layout graph (the ``fused_kernels=False`` oracle).
    """
    nb, block, kb = _block_layout(g.size, k_frac)
    live = -(-g.size // block)      # rows containing any real data
    if not trim_pad:
        live = nb
    blocks = _to_blocks(g, live, block)
    _, idx = jax.lax.top_k(jnp.abs(blocks), kb)
    vals = jnp.take_along_axis(blocks, idx, axis=1)
    if live < nb:
        idx = jnp.concatenate(
            [idx, jnp.broadcast_to(jnp.arange(kb), (nb - live, kb))])
        vals = jnp.concatenate([vals, jnp.zeros((nb - live, kb), vals.dtype)])
    return {"idx": idx.astype(jnp.int32), "val": vals}


def leaf_sparse_gather(g: jax.Array, sparse, k_frac: float,
                       trim_pad: bool = False) -> jax.Array:
    """g.flat values at the sparse entry positions -> (nb, kb) f32.

    ``trim_pad=True`` (like :func:`leaf_topk`): rows past the data gather
    from pure zero padding, so their values are emitted as exact zeros
    without materializing the padded block rows — bit-identical, and the
    row order (hence any downstream reduction order) is unchanged.
    """
    nb, block, _ = _block_layout(g.size, k_frac)
    live = -(-g.size // block) if trim_pad else nb
    blocks = _to_blocks(g, live, block)
    gv = jnp.take_along_axis(blocks, sparse["idx"][:live], axis=1)
    if live < nb:
        gv = jnp.concatenate(
            [gv, jnp.zeros((nb - live,) + gv.shape[1:], gv.dtype)])
    return gv


def leaf_scatter(sparse, shape, size: int, k_frac: float,
                 dtype=jnp.float32) -> jax.Array:
    nb, block, _ = _block_layout(size, k_frac)
    dense = jnp.zeros((nb, block), jnp.float32)
    dense = jnp.put_along_axis(dense, sparse["idx"], sparse["val"], axis=1,
                               inplace=False)
    return dense.reshape(-1)[:size].reshape(shape).astype(dtype)


def topk_count(size: int, k_frac: float) -> int:
    nb, _, kb = _block_layout(size, k_frac)
    return nb * kb


def init_topk_lbg(params_like, k_frac: float) -> Dict[str, Dict[str, jax.Array]]:
    out = {}
    for name, leaf in params_like.items():
        nb, _, kb = _block_layout(leaf.size, k_frac)
        out[name] = {"idx": jnp.zeros((nb, kb), jnp.int32),
                     "val": jnp.zeros((nb, kb), jnp.float32)}
    return out


def topk_step_core(grad: Dict[str, jax.Array], lbg, delta_threshold,
                   k_frac: float, *, corr=None, psum_axes=None,
                   out_dtypes=False, sparse_out=False, fused=False):
    """Shared body of the sparse-LBG Algorithm-1 step.

    grad: flat dict of dense leaves. lbg: flat dict of {idx, val}.
    corr: optional per-leaf replication-correction weights (each partial
    scalar is divided by corr[name] before reduction) and psum_axes the mesh
    axes to ``psum`` the three partial scalars over — both only used by the
    shard_map variant (repro.core.lbgm_sharded), which calls this on
    device-local shards. out_dtypes=True scatters g_tilde in each leaf's own
    dtype instead of fp32.

    ``fused=True`` replaces the three dense passes over each leaf (sparse
    gather, ||g||^2, block-wise top-k) with ONE pass through the fused
    Pallas kernel ``kernels.ops.lbgm_sparse_decision`` (batched over the
    client axis under ``vmap``); fp32-reassociation-equal to the default.

    ``sparse_out=True`` skips the dense ``leaf_scatter`` of g_tilde and
    instead returns ``((send, gscale), new_lbg, stats)`` where ``send`` is
    the per-leaf sparse {idx, val} payload carrying RAW values (the LBG's
    values on a recycle round, the fresh top-k values on a full round) and
    ``gscale`` is the scalar the server must fold in (``rho`` on a recycle
    round, ``1.0`` on a full round). This is the engine's sparse
    scalar-round aggregation contract: the aggregate contribution of client
    k is ``(w_k * gscale_k) * send_k`` scatter-added at ``send.idx`` — work
    proportional to what the round transmits, never O(M) per client.
    """
    # projection stats: dense g against sparse lbg — in fused mode the
    # gather, the squared norm, and the top-k candidates all come from one
    # read of g per leaf
    if fused:
        from repro.kernels.ops import lbgm_sparse_decision
    # sparse_out (the engine's sparse-aggregation mode) also unlocks the
    # bit-identical pad-row trims in leaf_topk/leaf_sparse_gather; the
    # plain dense-scatter mode keeps the exact legacy graph so
    # fused_kernels=False stays a faithful pre-optimization oracle
    trim = sparse_out or fused
    gl = jnp.zeros((), jnp.float32)
    ll = jnp.zeros((), jnp.float32)
    gg = jnp.zeros((), jnp.float32)
    fresh = {}
    for name, g in grad.items():
        sl = lbg[name]
        if fused:
            nb, block, _ = _block_layout(g.size, k_frac)
            blocks = _to_blocks(g, nb, block)
            gg_leaf, gv, ti, tv = lbgm_sparse_decision(blocks, sl["idx"])
            fresh[name] = {"idx": ti, "val": tv}
        else:
            gv = leaf_sparse_gather(g, sl, k_frac, trim_pad=trim)
            flat = g.reshape(-1).astype(jnp.float32)
            gg_leaf = jnp.vdot(flat, flat)
            fresh[name] = None  # computed below, preserving legacy op order
        c = 1.0 if corr is None else 1.0 / corr[name]
        gl += c * jnp.vdot(gv, sl["val"])
        ll += c * jnp.vdot(sl["val"], sl["val"])
        gg += c * gg_leaf
    if psum_axes is not None:
        gl = jax.lax.psum(gl, psum_axes)
        ll = jax.lax.psum(ll, psum_axes)
        gg = jax.lax.psum(gg, psum_axes)
    sin2, rho, scalar = decision_from_scalars(gl, gg, ll, delta_threshold)

    g_tilde, new_lbg = {}, {}
    total_k = 0
    for name, g in grad.items():
        sl = lbg[name]
        total_k += sl["idx"].size
        new = fresh[name] if fused else leaf_topk(g, k_frac, trim_pad=trim)
        keep_idx = jnp.where(scalar, sl["idx"], new["idx"])
        keep_val = jnp.where(scalar, sl["val"], new["val"])
        if sparse_out:
            # raw values; the server folds gscale (rho | 1) into its weight
            g_tilde[name] = {"idx": keep_idx, "val": keep_val}
        else:
            # scalar round: rho * dense(lbg); full round: dense(topk(g))
            send = {"idx": keep_idx,
                    "val": jnp.where(scalar, rho * sl["val"], new["val"])}
            g_tilde[name] = leaf_scatter(
                send, g.shape, g.size, k_frac,
                dtype=g.dtype if out_dtypes else jnp.float32)
        new_lbg[name] = {"idx": keep_idx, "val": keep_val}
    stats = topk_uplink_stats(sin2, rho, scalar, gg, total_k)
    if sparse_out:
        gscale = jnp.where(scalar, rho, 1.0)
        return (g_tilde, gscale), new_lbg, stats
    return g_tilde, new_lbg, stats


def lbgm_topk_client_step(grad: Dict[str, jax.Array], lbg, delta_threshold,
                          k_frac: float, sparse_out: bool = False,
                          fused: bool = False):
    """LBGM stacked on top-K with sparse LBG storage.

    grad: flat dict of dense leaves. lbg: flat dict of {idx, val}.
    See :func:`topk_step_core` for ``sparse_out`` / ``fused``.
    """
    return topk_step_core(grad, lbg, delta_threshold, k_frac,
                          sparse_out=sparse_out, fused=fused)


# --------------------------------------------------- threshold schedules

def corollary1_threshold(grad_sq_norm, tau: int, total_rounds: int):
    """Adaptive delta from Corollary 1: sin^2(alpha) <= eta / ||d||^2 with
    eta = 1/sqrt(tau*T) and d = g/tau (normalized ASG)."""
    eta = 1.0 / jnp.sqrt(float(tau * total_rounds))
    d_sq = grad_sq_norm / float(tau) ** 2
    return jnp.minimum(eta / jnp.maximum(d_sq, EPS), 1.0)
