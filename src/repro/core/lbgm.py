"""Look-back Gradient Multiplier (paper Algorithm 1) — the core contribution.

Per client k and round t, with accumulated stochastic gradient g and stored
look-back gradient (LBG) l:

    sin^2(alpha) = 1 - (<g,l> / (||g|| ||l||))^2          (LBP error, step 6)
    rho          = <g,l> / ||l||^2                        (LBC, step 8)
    if sin^2(alpha) <= delta:  upload the SCALAR rho; server uses rho*l
    else:                      upload g; both sides set l <- g

Two LBG storage variants:
  * ``full`` — dense LBG pytree (paper-faithful Algorithm 1).
  * ``topk`` — LBG kept as per-leaf (indices, values): LBGM stacked on top-K
    (paper §P3 plug-and-play + App. C.1 "LBG compression"), used for the
    >=34B assigned archs where K dense LBGs exceed pod HBM (DESIGN.md §3).
    Projection statistics use the *dense* current gradient against the
    sparse LBG (a tighter estimate than sparse-sparse, and a cheap gather);
    full-round uploads transmit top-K(g) and refresh the sparse LBG.

All decisions are ``jnp.where``-based (no data-dependent control flow) so
the aggregation program stays static for pjit/TPU.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.tree_math import (tree_scale, tree_select, tree_sq_norm,
                                  tree_vdot, tree_size)

EPS = 1e-20


class LBGMStats(NamedTuple):
    sin2: jax.Array          # LBP error
    rho: jax.Array           # LBC
    sent_scalar: jax.Array   # bool: True => only 1 float on the uplink
    uplink_floats: jax.Array # logical floats uploaded this round
    grad_sq_norm: jax.Array


def lbgm_stats(grad, lbg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(sin2, rho, gg). Degenerate LBG (zero) forces a full-gradient round."""
    gl = tree_vdot(grad, lbg)
    gg = tree_sq_norm(grad)
    ll = tree_sq_norm(lbg)
    cos2 = (gl * gl) / jnp.maximum(gg * ll, EPS)
    sin2 = jnp.where(ll > EPS, 1.0 - cos2, 1.0)
    rho = gl / jnp.maximum(ll, EPS)
    return sin2, rho, gg


def lbgm_client_step(grad, lbg, delta_threshold):
    """Paper Algorithm 1, worker side (variant='full').

    Returns (g_tilde as seen by the server, new_lbg, LBGMStats).
    """
    sin2, rho, gg = lbgm_stats(grad, lbg)
    # sin2 == 1.0 covers both degenerate LBGs (round 0) and orthogonal
    # gradients — either way a full round is strictly better.
    scalar = (sin2 <= delta_threshold) & (sin2 < 1.0)
    g_tilde = tree_select(scalar, tree_scale(lbg, rho), grad)
    new_lbg = tree_select(scalar, lbg, grad)
    m = tree_size(grad)
    stats = LBGMStats(sin2=sin2, rho=rho, sent_scalar=scalar,
                      uplink_floats=jnp.where(scalar, 1.0, float(m)),
                      grad_sq_norm=gg)
    return g_tilde, new_lbg, stats


# ------------------------------------------------------------- topk variant

BLOCK = 65536


def _block_layout(size: int, k_frac: float) -> Tuple[int, int, int]:
    """(nb, block, kb) for a leaf of `size`.

    Block-wise top-k (top-kb per contiguous block) instead of a global sort:
    (i) a full-vector sort would force XLA to all-gather multi-GB operands on
    a sharded mesh; (ii) block-LOCAL indices stay within int32 even for
    >2^31-element leaves (stacked 88-layer FFN grads). nb is rounded up to a
    multiple of 16 so the sparse LBG can shard over the model axis.
    """
    block = min(size, BLOCK)
    nb = -(-size // block)
    if nb > 1:
        nb = -(-nb // 16) * 16
    k = max(1, int(size * k_frac))
    kb = max(1, min(block, k // nb if nb > 1 else k))
    return nb, block, kb


def _to_blocks(g: jax.Array, nb: int, block: int) -> jax.Array:
    flat = g.reshape(-1).astype(jnp.float32)
    pad = nb * block - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(nb, block)


def leaf_topk(g: jax.Array, k_frac: float):
    """Block-wise top-|.|: returns ({'idx': (nb,kb) block-local int32,
    'val': (nb,kb) f32})."""
    nb, block, kb = _block_layout(g.size, k_frac)
    blocks = _to_blocks(g, nb, block)
    _, idx = jax.lax.top_k(jnp.abs(blocks), kb)
    vals = jnp.take_along_axis(blocks, idx, axis=1)
    return {"idx": idx.astype(jnp.int32), "val": vals}


def leaf_sparse_gather(g: jax.Array, sparse, k_frac: float) -> jax.Array:
    """g.flat values at the sparse entry positions -> (nb, kb) f32."""
    nb, block, _ = _block_layout(g.size, k_frac)
    blocks = _to_blocks(g, nb, block)
    return jnp.take_along_axis(blocks, sparse["idx"], axis=1)


def leaf_scatter(sparse, shape, size: int, k_frac: float,
                 dtype=jnp.float32) -> jax.Array:
    nb, block, _ = _block_layout(size, k_frac)
    dense = jnp.zeros((nb, block), jnp.float32)
    dense = jnp.put_along_axis(dense, sparse["idx"], sparse["val"], axis=1,
                               inplace=False)
    return dense.reshape(-1)[:size].reshape(shape).astype(dtype)


def topk_count(size: int, k_frac: float) -> int:
    nb, _, kb = _block_layout(size, k_frac)
    return nb * kb


def init_topk_lbg(params_like, k_frac: float) -> Dict[str, Dict[str, jax.Array]]:
    out = {}
    for name, leaf in params_like.items():
        nb, _, kb = _block_layout(leaf.size, k_frac)
        out[name] = {"idx": jnp.zeros((nb, kb), jnp.int32),
                     "val": jnp.zeros((nb, kb), jnp.float32)}
    return out


def topk_step_core(grad: Dict[str, jax.Array], lbg, delta_threshold,
                   k_frac: float, *, corr=None, psum_axes=None,
                   out_dtypes=False):
    """Shared body of the sparse-LBG Algorithm-1 step.

    grad: flat dict of dense leaves. lbg: flat dict of {idx, val}.
    corr: optional per-leaf replication-correction weights (each partial
    scalar is divided by corr[name] before reduction) and psum_axes the mesh
    axes to ``psum`` the three partial scalars over — both only used by the
    shard_map variant (repro.core.lbgm_sharded), which calls this on
    device-local shards. out_dtypes=True scatters g_tilde in each leaf's own
    dtype instead of fp32.
    """
    # projection stats: dense g against sparse lbg
    gl = jnp.zeros((), jnp.float32)
    ll = jnp.zeros((), jnp.float32)
    gg = jnp.zeros((), jnp.float32)
    for name, g in grad.items():
        sl = lbg[name]
        gv = leaf_sparse_gather(g, sl, k_frac)
        c = 1.0 if corr is None else 1.0 / corr[name]
        gl += c * jnp.vdot(gv, sl["val"])
        ll += c * jnp.vdot(sl["val"], sl["val"])
        flat = g.reshape(-1).astype(jnp.float32)
        gg += c * jnp.vdot(flat, flat)
    if psum_axes is not None:
        gl = jax.lax.psum(gl, psum_axes)
        ll = jax.lax.psum(ll, psum_axes)
        gg = jax.lax.psum(gg, psum_axes)
    cos2 = (gl * gl) / jnp.maximum(gg * ll, EPS)
    sin2 = jnp.where(ll > EPS, 1.0 - cos2, 1.0)
    rho = gl / jnp.maximum(ll, EPS)
    scalar = (sin2 <= delta_threshold) & (sin2 < 1.0)

    g_tilde, new_lbg = {}, {}
    total_k = 0
    for name, g in grad.items():
        sl = lbg[name]
        total_k += sl["idx"].size
        new = leaf_topk(g, k_frac)
        # scalar round: rho * dense(lbg); full round: dense(topk(g))
        send = {"idx": jnp.where(scalar, sl["idx"], new["idx"]),
                "val": jnp.where(scalar, rho * sl["val"], new["val"])}
        g_tilde[name] = leaf_scatter(
            send, g.shape, g.size, k_frac,
            dtype=g.dtype if out_dtypes else jnp.float32)
        new_lbg[name] = {"idx": jnp.where(scalar, sl["idx"], new["idx"]),
                         "val": jnp.where(scalar, sl["val"], new["val"])}
    # full round uplink: k values + k indices ~ 1.5 floats per kept value
    stats = LBGMStats(sin2=sin2, rho=rho, sent_scalar=scalar,
                      uplink_floats=jnp.where(scalar, 1.0, 1.5 * total_k),
                      grad_sq_norm=gg)
    return g_tilde, new_lbg, stats


def lbgm_topk_client_step(grad: Dict[str, jax.Array], lbg, delta_threshold,
                          k_frac: float):
    """LBGM stacked on top-K with sparse LBG storage.

    grad: flat dict of dense leaves. lbg: flat dict of {idx, val}.
    """
    return topk_step_core(grad, lbg, delta_threshold, k_frac)


# --------------------------------------------------- threshold schedules

def corollary1_threshold(grad_sq_norm, tau: int, total_rounds: int):
    """Adaptive delta from Corollary 1: sin^2(alpha) <= eta / ||d||^2 with
    eta = 1/sqrt(tau*T) and d = g/tau (normalized ASG)."""
    eta = 1.0 / jnp.sqrt(float(tau * total_rounds))
    d_sq = grad_sq_norm / float(tau) ** 2
    return jnp.minimum(eta / jnp.maximum(d_sq, EPS), 1.0)
