"""Shard-local LBGM (beyond-paper §Perf optimization).

The pjit formulation of topk-LBGM reconstructs a dense fp32 gradient from a
flat block layout; GSPMD has to reshard that M-sized tensor back to the
parameter layout, which costs ~4x params of all-gather per client on the
FSDP archs (measured: 6.2 TiB/client for llama4 — EXPERIMENTS.md §Perf).

Fix: run Algorithm 1's top-k variant under ``shard_map`` — every device
performs the block-wise top-k, sparse gather and scatter on its OWN shard of
the gradient; the only cross-device traffic is the psum of three partial
scalars (<g,l>, ||g||^2, ||l||^2) per client. The LBG is stored in the same
block layout, sharded exactly like the gradient. Semantics are identical to
``lbgm_topk_client_step`` up to the block boundaries (blocks now align with
shards, which is the better layout anyway).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.lbgm import (LBGMStats, _block_layout, _to_blocks,
                             decision_from_scalars, topk_step_core,
                             topk_uplink_stats)

# newer jax promotes shard_map to the top level; on the 0.4.x line it
# lives in jax.experimental. The replication-check kwarg was also renamed
# (check_rep -> check_vma) on its own schedule, so detect it by signature.
import inspect as _inspect

_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map
_SM_KW = ({"check_vma": False}
          if "check_vma" in _inspect.signature(_shard_map).parameters
          else {"check_rep": False})


def _spec_axes(spec: P) -> Tuple[str, ...]:
    out = []
    for e in spec:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            out.append(a)
    return tuple(out)


def _nshards(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def local_leaf_size(leaf_shape, spec: P, mesh: Mesh) -> int:
    n = 1
    for i, d in enumerate(leaf_shape):
        e = spec[i] if i < len(spec) else None
        div = 1
        if e is not None:
            for a in (e if isinstance(e, tuple) else (e,)):
                div *= mesh.shape[a]
        n *= d // div
    return n


def sharded_lbg_layout(params_like, gspecs: Dict[str, P], mesh: Mesh,
                       k_frac: float):
    """Returns (lbg SDS pytree, lbg NamedSharding pytree)."""
    sds, sh = {}, {}
    for name, leaf in params_like.items():
        axes = _spec_axes(gspecs[name])
        ns = _nshards(mesh, axes)
        nb, _, kb = _block_layout(local_leaf_size(leaf.shape, gspecs[name],
                                                  mesh), k_frac)
        shape = (nb * ns, kb)
        sds[name] = {"idx": jax.ShapeDtypeStruct(shape, jnp.int32),
                     "val": jax.ShapeDtypeStruct(shape, jnp.float32)}
        spec = P(axes if axes else None, None)
        sh[name] = {"idx": NamedSharding(mesh, spec),
                    "val": NamedSharding(mesh, spec)}
    return sds, sh


def init_sharded_lbg(params_like, gspecs, mesh, k_frac: float):
    sds, _ = sharded_lbg_layout(params_like, gspecs, mesh, k_frac)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def make_local_topk_step(delta: float, k_frac: float, *, corr=None,
                         psum_axes=None, out_dtypes=False, sparse_out=False,
                         fused=False):
    """Device-local Algorithm-1 top-k step: ``fn(grads, lbg)``.

    This is the single decision body both sharded execution modes share:

    * model-axis sharding (``make_sharded_topk_step``) calls it on gradient
      *shards* with ``corr``/``psum_axes`` so the three partial scalars are
      reduced across devices;
    * client-axis sharding (``repro.fed.engine.ShardedTopKLBGStore``) calls
      it with no psum at all — each device holds its local clients' full
      dense gradients and their (idx, val) bank rows, so the accept/recycle
      decision is entirely device-local and the only cross-device traffic
      of the round is the server aggregate's psum.

    ``sparse_out`` / ``fused`` pass through to :func:`topk_step_core`
    (sparse scalar-round aggregation payload / one-pass Pallas decision).
    """
    def step(grads, lbg):
        return topk_step_core(grads, lbg, delta, k_frac, corr=corr,
                              psum_axes=psum_axes, out_dtypes=out_dtypes,
                              sparse_out=sparse_out, fused=fused)
    return step


def model_shard_rows(nb: int, n_model: int) -> int:
    """Block rows of an ``(nb, kb)`` block-layout leaf each model rank owns
    under ``n_model``-way model-axis sharding, or 0 when the leaf cannot
    shard (``nb`` not divisible — e.g. single-block leaves like biases,
    which stay replicated and are counted once via a rank-0 gate).

    ``_block_layout`` rounds multi-block ``nb`` up to a multiple of 16
    precisely so the usual power-of-two model meshes divide it.
    """
    if n_model > 1 and nb % n_model == 0:
        return nb // n_model
    return 0


def bank_model_partition(params_like, k_frac: float,
                         n_model: int) -> Dict[str, bool]:
    """name -> whether that leaf's sparse-bank block rows shard over the
    model axis. The single place the divisibility rule lives: the engine's
    bank placement (``ShardedScheduler.layout_banks``) and the decision
    body (:func:`make_mesh_topk_step`) both derive from it, so the bank a
    device holds is always exactly the rows its decision reads."""
    return {name: model_shard_rows(_block_layout(leaf.size, k_frac)[0],
                                   n_model) > 0
            for name, leaf in params_like.items()}


def make_mesh_topk_step(delta: float, k_frac: float, *, n_model: int,
                        model_axis: str = "model", sparse_out: bool = True,
                        fused: bool = False, pre_blocked: bool = False,
                        layouts: Dict[str, Tuple[int, int, int]] = None):
    """Per-client Algorithm-1 decision body for the engine's 2-D
    ``(clients, model)`` mesh: ``fn(grads, lbg) -> ((send, gscale),
    new_lbg, stats)``.

    This is :func:`make_sharded_topk_step`'s decomposition run along the
    *model* axis of a mesh the caller is already shard-mapped over (the
    "sharded" client scheduler), rather than a standalone shard_map:

    * ``n_model == 1`` — exactly :func:`make_local_topk_step`, the fully
      device-local body (bit-for-bit the 1-D client-mesh path).
    * ``n_model > 1`` — each model rank processes only its
      ``nb / n_model`` rows of every leaf's *global* block layout
      (``jax.lax.axis_index(model_axis)`` picks the slice, matching the
      rows of the bank shard it holds); the three partial scalars
      (<g,l>, ||g||^2, ||l||^2) are ``psum``-reduced over ``model_axis``.
      Leaves whose ``nb`` does not divide (see
      :func:`bank_model_partition`) are processed whole on every rank and
      gated to rank 0 before the psum — counted exactly once, with no
      replication-correction division to round.

    The *global* block layout (and therefore ``stats.uplink_floats``) is
    mesh-shape independent: every mesh shape reports identical uplink
    accounting. Only ``sparse_out=True`` is supported for ``n_model > 1``
    (the dense g_tilde scatter would need a cross-rank leaf assembly; the
    engine's sparse aggregation contract never materializes it).

    ``pre_blocked=True`` is the ``model_sharding="auto"`` entry point: the
    caller (the scheduler's inner manual-over-``model`` region) hands each
    leaf ALREADY in block-row layout, pre-sliced to this rank's rows for
    sharded leaves (full rows for replicated ones) — tensor-parallel
    gradients re-lay out once at the nested shard_map boundary instead of
    replicate-then-slice. ``layouts`` must then carry the GLOBAL
    ``name -> (nb, block, kb)`` layout (the local row count no longer
    determines it), and the step runs even at ``n_model == 1`` (the psums
    collapse to identities) so the auto path has one body on every mesh.
    """
    if pre_blocked:
        if layouts is None:
            raise ValueError(
                "make_mesh_topk_step: pre_blocked=True needs the global "
                "`layouts` {name: (nb, block, kb)} — local rows cannot "
                "reconstruct the mesh-independent block layout")
        if not sparse_out:
            raise ValueError(
                "make_mesh_topk_step: pre_blocked=True requires "
                "sparse_out=True (block-row inputs have no dense g_tilde "
                "layout to scatter back into)")
    elif n_model == 1:
        return make_local_topk_step(delta, k_frac, sparse_out=sparse_out,
                                    fused=fused)
    if not sparse_out:
        raise ValueError(
            "make_mesh_topk_step: model-axis sharding (n_model > 1) "
            "requires the sparse aggregation contract (sparse_out=True); "
            "the dense per-client g_tilde cannot be assembled device-local")

    def step(grads, lbg):
        if fused:
            from repro.kernels.ops import lbgm_sparse_decision
        rank = jax.lax.axis_index(model_axis)
        gl = jnp.zeros((), jnp.float32)
        ll = jnp.zeros((), jnp.float32)
        gg = jnp.zeros((), jnp.float32)
        local = {}     # per-leaf local block rows (or fused (ti, tv))
        total_k = 0    # GLOBAL kept-entry count: mesh-independent uplink
        for name, g in grads.items():
            sl = lbg[name]
            if pre_blocked:
                nb, block, kb = layouts[name]
            else:
                nb, block, kb = _block_layout(g.size, k_frac)
            total_k += nb * kb
            nb_l = sl["idx"].shape[0]
            sharded = nb_l != nb
            assert nb_l == (nb // n_model if sharded else nb), (
                name, nb_l, nb, n_model)
            if pre_blocked:
                # block rows arrive from the nested shard_map boundary —
                # the caller's in_specs already handed this rank its slice
                assert g.shape == (nb_l, block), (name, g.shape, nb_l, block)
                bl = g
            else:
                bl = _to_blocks(g, nb, block)
                if sharded:
                    bl = jax.lax.dynamic_slice_in_dim(bl, rank * nb_l, nb_l,
                                                      axis=0)
            if fused:
                gg_leaf, gv, ti, tv = lbgm_sparse_decision(bl, sl["idx"])
                local[name] = (ti, tv)
            else:
                gv = jnp.take_along_axis(bl, sl["idx"], axis=1)
                gg_leaf = jnp.vdot(bl, bl)
                local[name] = bl
            pgl = jnp.vdot(gv, sl["val"])
            pll = jnp.vdot(sl["val"], sl["val"])
            pgg = gg_leaf
            if not sharded:
                # replicated leaf: every rank computed the same full-leaf
                # partials — count them once, exactly (a rank-0 gate, not
                # a 1/n division the psum would have to un-round)
                own = (rank == 0).astype(jnp.float32)
                pgl, pll, pgg = pgl * own, pll * own, pgg * own
            gl, ll, gg = gl + pgl, ll + pll, gg + pgg
        gl = jax.lax.psum(gl, model_axis)
        ll = jax.lax.psum(ll, model_axis)
        gg = jax.lax.psum(gg, model_axis)
        # the decision rule itself lives in ONE place (core.lbgm) — this
        # decomposition only changed how the three scalars were reduced
        sin2, rho, scalar = decision_from_scalars(gl, gg, ll, delta)

        send, new_lbg = {}, {}
        for name, g in grads.items():
            sl = lbg[name]
            kb = sl["idx"].shape[1]
            if fused:
                ti, tv = local[name]
            else:
                bl = local[name]
                _, ti = jax.lax.top_k(jnp.abs(bl), kb)
                tv = jnp.take_along_axis(bl, ti, axis=1)
                ti = ti.astype(jnp.int32)
            keep = {"idx": jnp.where(scalar, sl["idx"], ti),
                    "val": jnp.where(scalar, sl["val"], tv)}
            send[name] = keep
            new_lbg[name] = keep
        stats = topk_uplink_stats(sin2, rho, scalar, gg, total_k)
        gscale = jnp.where(scalar, rho, 1.0)
        return (send, gscale), new_lbg, stats

    return step


def make_sharded_topk_step(cfg, mesh: Mesh, gspecs: Dict[str, P],
                           delta: float):
    """Returns fn(grads, lbg) -> (g_tilde, new_lbg, LBGMStats), where grads
    follow gspecs and lbg follows sharded_lbg_layout."""
    k_frac = cfg.lbgm.k_frac
    all_axes = tuple(mesh.axis_names)
    total_dev = math.prod(mesh.shape[a] for a in all_axes)
    lbg_specs = {name: {"idx": P(_spec_axes(gspecs[name]) or None, None),
                        "val": P(_spec_axes(gspecs[name]) or None, None)}
                 for name in gspecs}
    # replication correction: leaves not sharded over some axes are summed
    # that many extra times by the global psum
    corr = {name: total_dev / _nshards(mesh, _spec_axes(gspecs[name]))
            for name in gspecs}

    local_fn = make_local_topk_step(delta, k_frac, corr=corr,
                                    psum_axes=all_axes, out_dtypes=True)

    stat_spec = LBGMStats(*([P()] * 5))
    return _shard_map(
        local_fn, mesh=mesh,
        in_specs=(gspecs, lbg_specs),
        out_specs=(gspecs, lbg_specs, stat_spec),
        **_SM_KW)
