"""Shard-local LBGM (beyond-paper §Perf optimization).

The pjit formulation of topk-LBGM reconstructs a dense fp32 gradient from a
flat block layout; GSPMD has to reshard that M-sized tensor back to the
parameter layout, which costs ~4x params of all-gather per client on the
FSDP archs (measured: 6.2 TiB/client for llama4 — EXPERIMENTS.md §Perf).

Fix: run Algorithm 1's top-k variant under ``shard_map`` — every device
performs the block-wise top-k, sparse gather and scatter on its OWN shard of
the gradient; the only cross-device traffic is the psum of three partial
scalars (<g,l>, ||g||^2, ||l||^2) per client. The LBG is stored in the same
block layout, sharded exactly like the gradient. Semantics are identical to
``lbgm_topk_client_step`` up to the block boundaries (blocks now align with
shards, which is the better layout anyway).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.lbgm import LBGMStats, _block_layout, topk_step_core

# newer jax promotes shard_map to the top level; on the 0.4.x line it
# lives in jax.experimental. The replication-check kwarg was also renamed
# (check_rep -> check_vma) on its own schedule, so detect it by signature.
import inspect as _inspect

_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map
_SM_KW = ({"check_vma": False}
          if "check_vma" in _inspect.signature(_shard_map).parameters
          else {"check_rep": False})


def _spec_axes(spec: P) -> Tuple[str, ...]:
    out = []
    for e in spec:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            out.append(a)
    return tuple(out)


def _nshards(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def local_leaf_size(leaf_shape, spec: P, mesh: Mesh) -> int:
    n = 1
    for i, d in enumerate(leaf_shape):
        e = spec[i] if i < len(spec) else None
        div = 1
        if e is not None:
            for a in (e if isinstance(e, tuple) else (e,)):
                div *= mesh.shape[a]
        n *= d // div
    return n


def sharded_lbg_layout(params_like, gspecs: Dict[str, P], mesh: Mesh,
                       k_frac: float):
    """Returns (lbg SDS pytree, lbg NamedSharding pytree)."""
    sds, sh = {}, {}
    for name, leaf in params_like.items():
        axes = _spec_axes(gspecs[name])
        ns = _nshards(mesh, axes)
        nb, _, kb = _block_layout(local_leaf_size(leaf.shape, gspecs[name],
                                                  mesh), k_frac)
        shape = (nb * ns, kb)
        sds[name] = {"idx": jax.ShapeDtypeStruct(shape, jnp.int32),
                     "val": jax.ShapeDtypeStruct(shape, jnp.float32)}
        spec = P(axes if axes else None, None)
        sh[name] = {"idx": NamedSharding(mesh, spec),
                    "val": NamedSharding(mesh, spec)}
    return sds, sh


def init_sharded_lbg(params_like, gspecs, mesh, k_frac: float):
    sds, _ = sharded_lbg_layout(params_like, gspecs, mesh, k_frac)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def make_local_topk_step(delta: float, k_frac: float, *, corr=None,
                         psum_axes=None, out_dtypes=False, sparse_out=False,
                         fused=False):
    """Device-local Algorithm-1 top-k step: ``fn(grads, lbg)``.

    This is the single decision body both sharded execution modes share:

    * model-axis sharding (``make_sharded_topk_step``) calls it on gradient
      *shards* with ``corr``/``psum_axes`` so the three partial scalars are
      reduced across devices;
    * client-axis sharding (``repro.fed.engine.ShardedTopKLBGStore``) calls
      it with no psum at all — each device holds its local clients' full
      dense gradients and their (idx, val) bank rows, so the accept/recycle
      decision is entirely device-local and the only cross-device traffic
      of the round is the server aggregate's psum.

    ``sparse_out`` / ``fused`` pass through to :func:`topk_step_core`
    (sparse scalar-round aggregation payload / one-pass Pallas decision).
    """
    def step(grads, lbg):
        return topk_step_core(grads, lbg, delta, k_frac, corr=corr,
                              psum_axes=psum_axes, out_dtypes=out_dtypes,
                              sparse_out=sparse_out, fused=fused)
    return step


def make_sharded_topk_step(cfg, mesh: Mesh, gspecs: Dict[str, P],
                           delta: float):
    """Returns fn(grads, lbg) -> (g_tilde, new_lbg, LBGMStats), where grads
    follow gspecs and lbg follows sharded_lbg_layout."""
    k_frac = cfg.lbgm.k_frac
    all_axes = tuple(mesh.axis_names)
    total_dev = math.prod(mesh.shape[a] for a in all_axes)
    lbg_specs = {name: {"idx": P(_spec_axes(gspecs[name]) or None, None),
                        "val": P(_spec_axes(gspecs[name]) or None, None)}
                 for name in gspecs}
    # replication correction: leaves not sharded over some axes are summed
    # that many extra times by the global psum
    corr = {name: total_dev / _nshards(mesh, _spec_axes(gspecs[name]))
            for name in gspecs}

    local_fn = make_local_topk_step(delta, k_frac, corr=corr,
                                    psum_axes=all_axes, out_dtypes=True)

    stat_spec = LBGMStats(*([P()] * 5))
    return _shard_map(
        local_fn, mesh=mesh,
        in_specs=(gspecs, lbg_specs),
        out_specs=(gspecs, lbg_specs, stat_spec),
        **_SM_KW)
