"""RWKV-6 "Finch" time-mixing block (arXiv:2404.05892), chunked for TPU.

Recurrence per head (state S in R^{dk x dv}):
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
with *data-dependent* per-channel decay w_t = exp(-exp(w0 + lora(x_t))).

TPU adaptation: the per-step scan would serialize 4k-512k steps and blow up
saved activations; we use the standard chunked linear-attention form (chunk
size 64, fp32 internals): within-chunk interactions become a masked matmul on
decay-rescaled r/k, cross-chunk state is carried by a short scan. The Pallas
kernel version lives in ``repro.kernels.rwkv6_scan``; this jnp version is the
lowering/roofline path and the oracle's chunked counterpart.

Simplification vs the full Finch block (documented in DESIGN.md): static
learned token-shift mixing coefficients per projection (mu), with the
data-dependent LoRA applied to the decay only (the headline Finch feature).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamStore, group_norm_heads, silu

LORA_DIM = 64
CHUNK = 64
EXP_CLAMP = 60.0


def init_rwkv6(store: ParamStore, prefix: str, cfg: ArchConfig, stack: int = 0):
    d = cfg.d_model
    lead = (stack,) if stack else ()
    lax_ = ("layers",) if stack else ()
    for name in ("r", "k", "v", "g", "o"):
        store.param(f"{prefix}/w_{name}", lead + (d, d),
                    lax_ + ("embed", "embed2"))
    for name in ("r", "k", "v", "g", "w"):
        store.param(f"{prefix}/mu_{name}", lead + (d,), lax_ + ("embed",),
                    init="uniform", scale=0.5)
    store.param(f"{prefix}/w0", lead + (d,), lax_ + ("embed",), init="zeros")
    store.param(f"{prefix}/lora_a", lead + (d, LORA_DIM),
                lax_ + ("embed", "lora"), scale=0.01)
    store.param(f"{prefix}/lora_b", lead + (LORA_DIM, d),
                lax_ + ("lora", "embed"), scale=0.01)
    store.param(f"{prefix}/u", lead + (d,), lax_ + ("embed",),
                init="uniform", scale=0.5)
    store.param(f"{prefix}/ln_g", lead + (d,), lax_ + ("embed",), init="ones")


def _shift(x):
    """token shift: x_{t-1} (zeros at t=0)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def chunked_wkv(r, k, v, logw, u, *, chunk: int = CHUNK, state0=None,
                unroll: bool = False):
    """Chunked RWKV6 recurrence.

    r,k,v: (B, T, H, hd); logw: (B, T, H, hd) (log decay, <= 0); u: (H, hd).
    Returns (out (B,T,H,hd) fp32, final state (B,H,hd,hd) fp32).
    """
    B, T, H, hd = r.shape
    assert T % chunk == 0 or T < chunk, (T, chunk)
    c = min(chunk, T)
    n = T // c
    f32 = jnp.float32
    r, k, v, logw = (a.astype(f32) for a in (r, k, v, logw))
    rs = r.reshape(B, n, c, H, hd).transpose(1, 0, 3, 2, 4)   # (n,B,H,c,hd)
    ks = k.reshape(B, n, c, H, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, n, c, H, hd).transpose(1, 0, 3, 2, 4)
    lw = logw.reshape(B, n, c, H, hd).transpose(1, 0, 3, 2, 4)
    if state0 is None:
        state0 = jnp.zeros((B, H, hd, hd), f32)

    tri = jnp.tril(jnp.ones((c, c), f32), -1)                 # strict lower
    eye = jnp.eye(c, dtype=f32)

    def body(S, xs):
        rc, kc, vc, lwc = xs                                  # (B,H,c,hd)
        cum = jnp.cumsum(lwc, axis=2)                         # c_t = sum_{s<=t}
        cum_in = cum - lwc                                    # c_{t-1}
        r_dec = rc * jnp.exp(cum_in)                          # r_i e^{c_{i-1}}
        # clamp: once a channel has decayed by e^-EXP_CLAMP within the chunk
        # its cross-position contribution is negligible; unclamped, exp(-cum)
        # overflows fp32 for aggressively-decaying channels (standard chunked
        # linear-attention trick).
        k_dec = kc * jnp.exp(jnp.minimum(-cum, EXP_CLAMP))    # k_j e^{-c_j}
        # intra-chunk: A[i,j] = sum_d r_i e^{c_{i-1}} k_j e^{-c_j}, j < i
        A = jnp.einsum("bhid,bhjd->bhij", r_dec, k_dec) * tri
        A += jnp.einsum("bhid,bhjd->bhij", rc * u[:, None, :], kc) * eye  # diag bonus
        out = jnp.einsum("bhij,bhjd->bhid", A, vc)
        out += jnp.einsum("bhid,bhde->bhie", r_dec, S)        # inter-chunk
        # state update: S <- diag(e^{c_chunk}) S + sum_j e^{c_chunk - c_j} k_j v_j^T
        total = cum[:, :, -1:, :]                             # (B,H,1,hd)
        S = S * jnp.exp(total).transpose(0, 1, 3, 2) + jnp.einsum(
            "bhjd,bhje->bhde", kc * jnp.exp(total - cum), vc)
        return S, out

    if unroll:   # dry-run cost pass (see ArchConfig.unroll)
        S = state0
        outs = []
        for i in range(n):
            S, o = body(S, (rs[i], ks[i], vs[i], lw[i]))
            outs.append(o)
        outs = jnp.stack(outs)
    else:
        S, outs = jax.lax.scan(body, state0, (rs, ks, vs, lw))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, hd)
    return out, S


def rwkv6_decay(p, xw: jax.Array) -> jax.Array:
    """log decay in (-inf, 0): -exp(w0 + tanh(x A) B)."""
    lora = jnp.einsum("btd,dl->btl", xw.astype(jnp.float32),
                      p["lora_a"].astype(jnp.float32))
    lora = jnp.einsum("btl,ld->btd", jnp.tanh(lora),
                      p["lora_b"].astype(jnp.float32))
    return -jnp.exp(p["w0"].astype(jnp.float32) + lora)


def apply_rwkv6(p, x: jax.Array, cfg: ArchConfig, state=None, shifted=None):
    """Time-mixing. x: (B,T,d). state/shifted given in decode mode.

    Returns (out, (new_state, last_x)) — the carries are used by serve_step.
    """
    B, T, d = x.shape
    H = cfg.n_heads
    hd = cfg.resolved_head_dim
    xs = _shift(x) if shifted is None else jnp.concatenate(
        [shifted[:, None], x[:, :-1]], axis=1)

    proj = {}
    for name in ("r", "k", "v", "g"):
        xm = _mix(x, xs, p[f"mu_{name}"])
        proj[name] = jnp.einsum("btd,de->bte", xm, p[f"w_{name}"])
    xw = _mix(x, xs, p["mu_w"])
    logw = rwkv6_decay(p, xw)                                 # (B,T,d) fp32

    r = proj["r"].reshape(B, T, H, hd)
    k = proj["k"].reshape(B, T, H, hd)
    v = proj["v"].reshape(B, T, H, hd)
    u = p["u"].astype(jnp.float32).reshape(H, hd)
    out, new_state = chunked_wkv(r, k, v, logw.reshape(B, T, H, hd), u,
                                 chunk=CHUNK if T >= CHUNK else T,
                                 state0=state, unroll=cfg.unroll)
    out = group_norm_heads(out, jnp.ones((hd,), jnp.float32))
    out = out.reshape(B, T, d).astype(x.dtype) * silu(proj["g"])
    out = jnp.einsum("btd,de->bte", out, p["w_o"])
    return out, (new_state, x[:, -1])


def rwkv6_decode_step(p, x1: jax.Array, cfg: ArchConfig, state, last_x):
    """Single-token decode: x1 (B,1,d); O(1) per token (recurrent form)."""
    out, (new_state, new_last) = apply_rwkv6(p, x1, cfg, state=state,
                                             shifted=last_x)
    return out, (new_state, new_last)
