"""RecurrentGemma / Griffin recurrent block: temporal conv + RG-LRU.

(arXiv:2402.19427). RG-LRU per channel:
    r_t = sigmoid(W_a x_t + b_a)         (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)         (input gate)
    a_t = a^(c * r_t),  a = sigmoid(Lambda),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: the sequential recurrence is evaluated with
``jax.lax.associative_scan`` (log-depth, parallel) in fp32 during training /
prefill, and as a single fused step during decode (O(1) state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamStore, silu

C_EXP = 8.0
CONV_W = 4


def init_rglru(store: ParamStore, prefix: str, cfg: ArchConfig, stack: int = 0):
    d = cfg.d_model
    lead = (stack,) if stack else ()
    lax_ = ("layers",) if stack else ()
    store.param(f"{prefix}/w_in", lead + (d, d), lax_ + ("embed", "embed2"))
    store.param(f"{prefix}/w_gate_branch", lead + (d, d),
                lax_ + ("embed", "embed2"))
    store.param(f"{prefix}/conv_w", lead + (CONV_W, d), lax_ + ("conv", "embed"),
                scale=0.1)
    store.param(f"{prefix}/conv_b", lead + (d,), lax_ + ("embed",), init="zeros")
    store.param(f"{prefix}/w_a", lead + (d, d), lax_ + ("embed", "embed2"))
    store.param(f"{prefix}/b_a", lead + (d,), lax_ + ("embed",), init="zeros")
    store.param(f"{prefix}/w_x", lead + (d, d), lax_ + ("embed", "embed2"))
    store.param(f"{prefix}/b_x", lead + (d,), lax_ + ("embed",), init="zeros")
    store.param(f"{prefix}/lam", lead + (d,), lax_ + ("embed",), init="uniform",
                scale=2.0)
    store.param(f"{prefix}/w_out", lead + (d, d), lax_ + ("embed", "embed2"))


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv width 4. x:(B,T,d), w:(4,d)."""
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], CONV_W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(CONV_W)) + b
    new_state = xp[:, -(CONV_W - 1):]
    return out, new_state


def _rglru_scan(a, bx, h0=None):
    """h_t = a_t h_{t-1} + bx_t via associative scan; a,bx: (B,T,d) fp32."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2
    aa, hh = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return hh


def apply_rglru(p, x: jax.Array, cfg: ArchConfig, state=None, conv_state=None):
    """Griffin recurrent block. x:(B,T,d) -> (out, (h_state, conv_state))."""
    gate = silu(jnp.einsum("btd,de->bte", x, p["w_gate_branch"]))
    xi = jnp.einsum("btd,de->bte", x, p["w_in"])
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)

    x32 = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", x32,
                                  p["w_a"].astype(jnp.float32)) +
                       p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("btd,de->bte", x32,
                                  p["w_x"].astype(jnp.float32)) +
                       p["b_x"].astype(jnp.float32))
    log_a0 = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
    log_a = C_EXP * r * log_a0                       # log a_t <= 0
    a = jnp.exp(log_a)
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x32)
    h = _rglru_scan(a, bx, h0=state)
    new_state = h[:, -1]
    out = (h.astype(x.dtype) * gate)
    out = jnp.einsum("btd,de->bte", out, p["w_out"])
    return out, (new_state, new_conv)


def rglru_decode_step(p, x1: jax.Array, cfg: ArchConfig, state, conv_state):
    """Single-token decode (sequential form, no scan)."""
    return apply_rglru(p, x1, cfg, state=state, conv_state=conv_state)
