"""Decoder-LM assembly covering all assigned architecture families.

Supports: dense GQA (llama/yi/deepseek/mistral/qwen3), MoE (mixtral/llama4),
SSM (rwkv6), hybrid RG-LRU+local-attn (recurrentgemma), enc-dec (whisper
backbone) and VLM early-fusion (qwen2-vl backbone, M-RoPE).

Homogeneous stacks (single-entry block_pattern, no enc-dec) are *stacked*
along a leading "layers" axis and run under ``lax.scan`` (+ optional remat);
heterogeneous patterns unroll.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv6_lib
from repro.models.attention import (attention, decode_attention, mrope_rotate,
                                    rope_rotate)
from repro.models.common import ParamStore, rms_norm, subtree, swiglu


# ------------------------------------------------------------------ init

def _init_attn(store: ParamStore, prefix: str, cfg: ArchConfig, stack: int,
               cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    lead = (stack,) if stack else ()
    lx = ("layers",) if stack else ()
    tag = "x" if cross else "a"
    store.param(f"{prefix}/w{tag}_q", lead + (d, nq * hd),
                lx + ("embed", "heads"))
    store.param(f"{prefix}/w{tag}_k", lead + (d, nkv * hd),
                lx + ("embed", "kv_heads"))
    store.param(f"{prefix}/w{tag}_v", lead + (d, nkv * hd),
                lx + ("embed", "kv_heads"))
    store.param(f"{prefix}/w{tag}_o", lead + (nq * hd, d),
                lx + ("heads", "embed"))
    if cfg.qk_norm and not cross:
        store.param(f"{prefix}/q_norm", lead + (hd,), lx + ("head_dim",),
                    init="ones")
        store.param(f"{prefix}/k_norm", lead + (hd,), lx + ("head_dim",),
                    init="ones")


def _init_ffn(store: ParamStore, prefix: str, cfg: ArchConfig, stack: int):
    if cfg.moe.num_experts:
        moe_lib.init_moe(store, prefix + "/moe", cfg, stack)
    else:
        d, ff = cfg.d_model, cfg.d_ff
        lead = (stack,) if stack else ()
        lx = ("layers",) if stack else ()
        store.param(f"{prefix}/w_gate", lead + (d, ff), lx + ("embed", "ff"))
        store.param(f"{prefix}/w_up", lead + (d, ff), lx + ("embed", "ff"))
        store.param(f"{prefix}/w_down", lead + (ff, d), lx + ("ff", "embed"))


def _init_block(store: ParamStore, prefix: str, cfg: ArchConfig, kind: str,
                stack: int = 0, cross: bool = False):
    d = cfg.d_model
    lead = (stack,) if stack else ()
    lx = ("layers",) if stack else ()
    store.param(f"{prefix}/norm1", lead + (d,), lx + ("embed",), init="ones")
    if kind in ("attn", "swa"):
        _init_attn(store, prefix, cfg, stack)
    elif kind == "rwkv6":
        rwkv6_lib.init_rwkv6(store, prefix + "/tmix", cfg, stack)
    elif kind == "rglru":
        rglru_lib.init_rglru(store, prefix + "/rec", cfg, stack)
    else:
        raise ValueError(kind)
    if cross:
        store.param(f"{prefix}/norm_x", lead + (d,), lx + ("embed",),
                    init="ones")
        _init_attn(store, prefix, cfg, stack, cross=True)
    store.param(f"{prefix}/norm2", lead + (d,), lx + ("embed",), init="ones")
    _init_ffn(store, prefix, cfg, stack)


def uses_scan(cfg: ArchConfig) -> bool:
    return (len(cfg.block_pattern) == 1 and not cfg.encdec
            and not cfg.unroll)


def init_lm(key: jax.Array, cfg: ArchConfig):
    """Returns (params flat dict, logical axes flat dict)."""
    import numpy as np
    dtype = jnp.dtype(cfg.dtype)
    store = ParamStore(key, dtype)
    d = cfg.d_model
    store.param("embed", (cfg.vocab_size, d), ("vocab", "embed"), scale=0.02)
    if cfg.encdec:
        for i in range(cfg.n_encoder_layers):
            _init_block(store, f"enc_{i:02d}", cfg, "attn")
        store.param("enc_norm", (d,), ("embed",), init="ones")
        for i in range(cfg.n_layers):
            _init_block(store, f"dec_{i:02d}", cfg, "attn", cross=True)
    elif uses_scan(cfg):
        _init_block(store, "blocks", cfg, cfg.block_pattern[0],
                    stack=cfg.n_layers)
    else:
        for i in range(cfg.n_layers):
            _init_block(store, f"layer_{i:02d}", cfg, cfg.block_kind(i))
    store.param("final_norm", (d,), ("embed",), init="ones")
    if not cfg.tie_embeddings:
        store.param("lm_head", (d, cfg.vocab_size), ("embed", "vocab"),
                    scale=0.02)
    return store.params, store.axes


# ------------------------------------------------------------------ fwd

def _apply_attn_train(p, x, cfg: ArchConfig, kind: str, positions, pos3,
                      window_override=None):
    B, T, d = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("btd,dh->bth", x, p["wa_q"]).reshape(B, T, nq, hd)
    k = jnp.einsum("btd,dh->bth", x, p["wa_k"]).reshape(B, T, nkv, hd)
    v = jnp.einsum("btd,dh->bth", x, p["wa_v"]).reshape(B, T, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope and pos3 is not None:
        q = mrope_rotate(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = mrope_rotate(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = rope_rotate(q, positions, cfg.rope_theta)
        k = rope_rotate(k, positions, cfg.rope_theta)
    window = window_override if window_override is not None else (
        cfg.sliding_window if kind == "swa" else None)
    o = attention(q, k, v, causal=True, window=window, unroll=cfg.unroll)
    return jnp.einsum("bth,hd->btd", o.reshape(B, T, nq * hd), p["wa_o"])


def _apply_cross_attn(p, x, enc_out, cfg: ArchConfig):
    B, T, d = x.shape
    Te = enc_out.shape[1]
    hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("btd,dh->bth", x, p["wx_q"]).reshape(B, T, nq, hd)
    k = jnp.einsum("btd,dh->bth", enc_out, p["wx_k"]).reshape(B, Te, nkv, hd)
    v = jnp.einsum("btd,dh->bth", enc_out, p["wx_v"]).reshape(B, Te, nkv, hd)
    o = attention(q, k, v, causal=False)
    return jnp.einsum("bth,hd->btd", o.reshape(B, T, nq * hd), p["wx_o"])


def _apply_ffn(p, x, cfg: ArchConfig):
    if cfg.moe.num_experts:
        return moe_lib.apply_moe(subtree(p, "moe"), x, cfg)
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), 0.0


def _apply_block_train(p, x, cfg: ArchConfig, kind: str, positions, pos3=None,
                       enc_out=None, causal_attn=True):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ("attn", "swa"):
        if causal_attn:
            h = _apply_attn_train(p, h, cfg, kind, positions, pos3)
        else:  # encoder self-attention
            B, T, d = h.shape
            hd, nq, nkv = (cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads)
            q = jnp.einsum("btd,dh->bth", h, p["wa_q"]).reshape(B, T, nq, hd)
            k = jnp.einsum("btd,dh->bth", h, p["wa_k"]).reshape(B, T, nkv, hd)
            v = jnp.einsum("btd,dh->bth", h, p["wa_v"]).reshape(B, T, nkv, hd)
            o = attention(q, k, v, causal=False)
            h = jnp.einsum("bth,hd->btd", o.reshape(B, T, nq * hd), p["wa_o"])
    elif kind == "rwkv6":
        h, _ = rwkv6_lib.apply_rwkv6(subtree(p, "tmix"), h, cfg)
    elif kind == "rglru":
        h, _ = rglru_lib.apply_rglru(subtree(p, "rec"), h, cfg)
    x = x + h
    if enc_out is not None:
        hx = rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + _apply_cross_attn(p, hx, enc_out, cfg)
    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    h2, aux = _apply_ffn(p, h2, cfg)
    return x + h2, aux


def _embed(params, cfg: ArchConfig, tokens):
    emb = jnp.take(params["embed"], tokens, axis=0)
    return emb


def build_mrope_positions(cfg: ArchConfig, B: int, T: int):
    """(3, B, T) positions: a vision grid of `vision_tokens` patches followed
    by sequential text positions (qwen2-vl style)."""
    nv = cfg.vision_tokens
    side = max(1, int(nv ** 0.5))
    idx = jnp.arange(T)
    is_vis = idx < nv
    t_pos = jnp.where(is_vis, 0, idx - nv + side)
    h_pos = jnp.where(is_vis, idx // side, idx - nv + side)
    w_pos = jnp.where(is_vis, idx % side, idx - nv + side)
    pos3 = jnp.stack([t_pos, h_pos, w_pos])                  # (3, T)
    return jnp.broadcast_to(pos3[:, None, :], (3, B, T))


def forward_hidden(params: Dict[str, jax.Array], cfg: ArchConfig,
                   tokens: jax.Array,
                   extra_embeds: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Backbone forward to the final hidden states. tokens (B,T) ->
    (hidden (B,T,d), aux loss).

    ``extra_embeds``: modality-stub embeddings. audio (enc-dec): encoder
    input frames (B, Te, d). vlm: patch embeddings (B, n_vis, d) that
    *overwrite* the first n_vis token embeddings (early fusion).
    """
    B, T = tokens.shape
    x = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    pos3 = None
    if cfg.mrope:
        pos3 = build_mrope_positions(cfg, B, T)
        if extra_embeds is not None:
            nv = extra_embeds.shape[1]
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x[:, nv:]],
                                axis=1)
    aux_total = 0.0

    enc_out = None
    if cfg.encdec:
        assert extra_embeds is not None, "enc-dec needs encoder frames"
        from repro.models.common import sinusoidal_positions
        e = extra_embeds.astype(x.dtype)
        e = e + sinusoidal_positions(e.shape[1], cfg.d_model).astype(x.dtype)
        for i in range(cfg.n_encoder_layers):
            e, aux = _apply_block_train(subtree(params, f"enc_{i:02d}"), e,
                                        cfg, "attn", None, causal_attn=False)
            aux_total += aux
        enc_out = rms_norm(e, params["enc_norm"], cfg.norm_eps)
        for i in range(cfg.n_layers):
            x, aux = _apply_block_train(subtree(params, f"dec_{i:02d}"), x,
                                        cfg, "attn", positions,
                                        enc_out=enc_out)
            aux_total += aux
    elif uses_scan(cfg):
        kind = cfg.block_pattern[0]
        stacked = subtree(params, "blocks")

        def body(carry, layer_p):
            h, aux_acc = carry
            h, aux = _apply_block_train(layer_p, h, cfg, kind, positions,
                                        pos3)
            return (h, aux_acc + aux), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux_total), _ = jax.lax.scan(body_fn, (x, 0.0), stacked)
    else:
        for i in range(cfg.n_layers):
            blk = functools.partial(
                _apply_block_train, subtree(params, f"layer_{i:02d}"), cfg=cfg,
                kind=cfg.block_kind(i), positions=positions, pos3=pos3)
            if cfg.remat:
                blk = jax.checkpoint(blk)
            x, aux = blk(x)
            aux_total += aux

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def _head(params, cfg: ArchConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params, cfg: ArchConfig, tokens, extra_embeds=None):
    """Full-logit forward (small models / tests). -> (logits (B,T,V), aux)."""
    x, aux = forward_hidden(params, cfg, tokens, extra_embeds)
    return jnp.einsum("btd,dv->btv", x, _head(params, cfg)), aux


def prefill_logits(params, cfg: ArchConfig, tokens, extra_embeds=None):
    """Inference prefill: hidden for all positions, head for the last one."""
    x, _ = forward_hidden(params, cfg, tokens, extra_embeds)
    return jnp.einsum("bd,dv->bv", x[:, -1], _head(params, cfg))


def lm_loss(params, cfg: ArchConfig, tokens, labels, extra_embeds=None,
            ce_chunk: int = 512) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE with a *chunked* softmax over T so the (B,T,V) logits
    never materialize (vocab up to 256k x 1M tokens would not fit).
    labels = next tokens (caller-shifted); negative labels are masked.
    """
    x, aux = forward_hidden(params, cfg, tokens, extra_embeds)
    head = _head(params, cfg)
    B, T, d = x.shape
    c = min(ce_chunk, T)
    assert T % c == 0, (T, c)
    n = T // c
    xs = x.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_ce(carry, args):
        xc, lc = args
        logits = jnp.einsum("bcd,dv->bcv", xc, head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc.clip(0)[..., None],
                                 axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        tot, cnt = carry
        return (tot + jnp.sum((lse - ll) * mask), cnt + jnp.sum(mask)), None

    if cfg.unroll:
        carry = (jnp.zeros(()), jnp.zeros(()))
        for i in range(n):
            carry, _ = chunk_ce(carry, (xs[i], ls[i]))
        tot, cnt = carry
    else:
        (tot, cnt), _ = jax.lax.scan(chunk_ce, (0.0, 0.0), (xs, ls))
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}
