"""Attention: GQA/MQA, RoPE, M-RoPE, causal + sliding-window, decode.

Training/prefill attention is *query-chunked* (flash-style outer loop via
``lax.scan``) so the (Tq, Tk) score tensor never materializes at full size —
this is the jnp reference path used for lowering/roofline; the Pallas TPU
kernel lives in ``repro.kernels.flash_attention``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------- RoPE

def rope_rotate(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) int32. Pairs (even, odd) halves."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,T,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope_rotate(x: jax.Array, positions3: jax.Array, sections, theta: float):
    """Multimodal RoPE (qwen2-vl): positions3 (3, B, T) for (t, h, w).

    The hd/2 frequency slots are partitioned into ``sections`` groups; slot
    group i uses positions3[i]. Equivalent to standard RoPE when the three
    position streams coincide (text tokens).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    sel = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                     total_repeat_length=hd // 2)          # (hd/2,) in {0,1,2}
    # gather per-slot positions: (B, T, hd/2)
    pos = jnp.einsum("sbt,cs->btc", positions3.astype(jnp.float32),
                     jax.nn.one_hot(sel, 3, dtype=jnp.float32))
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- core SDPA

def _sdpa_block(q, k, v, mask):
    """q:(B,cq,Hkv,g,hd) k/v:(B,Tk,Hkv,hd) mask:(cq,Tk) or None -> (B,cq,Hkv,g,hd)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, q_chunk: int = 1024,
              unroll: bool = False) -> jax.Array:
    """GQA attention. q:(B,Tq,Hq,hd), k/v:(B,Tk,Hkv,hd) -> (B,Tq,Hq,hd).

    ``q_offset``: absolute position of q[0] relative to k[0] (for caches).
    ``window``: sliding-window width (keys with qpos-kpos >= window masked).
    """
    B, Tq, Hq, hd = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    g = Hq // Hkv
    qg = q.reshape(B, Tq, Hkv, g, hd)

    def mask_for(qpos):
        kpos = jnp.arange(Tk)
        m = jnp.ones((qpos.shape[0], Tk), bool)
        if causal:
            m &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            m &= (qpos[:, None] - kpos[None, :]) < window
        return m

    if Tq <= q_chunk:
        qpos = jnp.arange(Tq) + q_offset
        need_mask = causal or (window is not None)
        o = _sdpa_block(qg, k, v, mask_for(qpos) if need_mask else None)
        return o.reshape(B, Tq, Hq, hd)

    while Tq % q_chunk:      # largest divisor of Tq not above q_chunk
        q_chunk -= 1
    n = Tq // q_chunk
    qs = qg.reshape(B, n, q_chunk, Hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)

    def body(_, args):
        i, qc = args
        qpos = i * q_chunk + jnp.arange(q_chunk) + q_offset
        return None, _sdpa_block(qc, k, v, mask_for(qpos))

    if unroll:   # dry-run cost pass: scan bodies are undercounted by XLA
        os = jnp.stack([body(None, (jnp.asarray(i), qs[i]))[1]
                        for i in range(n)])
    else:
        _, os = jax.lax.scan(body, None, (jnp.arange(n), qs))
    return os.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tq, Hq, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid_len) -> jax.Array:
    """Single-token decode. q:(B,1,Hq,hd); caches:(B,S,Hkv,hd)."""
    B, _, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(S)
    mask = kpos[None, :] < jnp.asarray(valid_len).reshape(-1, 1)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, hd).astype(q.dtype)
