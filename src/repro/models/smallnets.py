"""Paper-native small models: the CNN (S1) and FCN (S2) classifiers used in
the paper's FL experiments (Figs. 5-8), implemented in raw JAX.

Inputs are (B, 28, 28, 1) image-like arrays (synthetic stand-ins for
MNIST/FMNIST since the container is offline).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamStore

IMG = 28


def init_cnn(key, cfg: ArchConfig):
    store = ParamStore(key, jnp.float32)
    ch = cfg.d_model  # base width (32)
    chans = [1, ch, ch, 2 * ch, 2 * ch][: cfg.n_layers + 1]
    for i in range(cfg.n_layers):
        store.param(f"conv{i}/w", (3, 3, chans[i], chans[i + 1]),
                    ("kh", "kw", "cin", "cout"), scale=0.1)
        store.param(f"conv{i}/b", (chans[i + 1],), ("cout",), init="zeros")
    # two 2x2 maxpools -> 7x7 spatial
    feat = 7 * 7 * chans[cfg.n_layers]
    store.param("fc/w", (feat, cfg.vocab_size), ("feat", "classes"))
    store.param("fc/b", (cfg.vocab_size,), ("classes",), init="zeros")
    return store.params, store.axes


def apply_cnn(params, cfg: ArchConfig, x):
    """x: (B, 28, 28, 1) -> logits (B, classes)."""
    h = x
    for i in range(cfg.n_layers):
        h = jax.lax.conv_general_dilated(
            h, params[f"conv{i}/w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + params[f"conv{i}/b"])
        if i in (1, cfg.n_layers - 1):  # pool twice -> 7x7
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    return h @ params["fc/w"] + params["fc/b"]


def init_fcn(key, cfg: ArchConfig):
    store = ParamStore(key, jnp.float32)
    d = cfg.d_model
    store.param("fc1/w", (IMG * IMG, d), ("feat", "hidden"))
    store.param("fc1/b", (d,), ("hidden",), init="zeros")
    store.param("fc2/w", (d, cfg.vocab_size), ("hidden", "classes"))
    store.param("fc2/b", (cfg.vocab_size,), ("classes",), init="zeros")
    return store.params, store.axes


def apply_fcn(params, cfg: ArchConfig, x):
    h = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1/w"] + params["fc1/b"])
    return h @ params["fc2/w"] + params["fc2/b"]


def classifier_loss(apply_fn, params, cfg, x, y):
    logits = apply_fn(params, cfg, x)
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return ce, {"ce": ce, "acc": acc}
