"""Modality frontend STUBS (the one sanctioned carve-out, DESIGN.md §4).

``[audio]`` / ``[vlm]`` architectures specify the transformer backbone only;
these helpers produce the precomputed frame/patch embeddings the backbone
consumes — ShapeDtypeStructs for dry-runs, random arrays for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def extra_embed_shape(cfg: ArchConfig, batch: int):
    """Shape of the stub embedding input, or None for pure-text archs."""
    if cfg.encdec:
        return (batch, cfg.encoder_seq, cfg.d_model)
    if cfg.vision_tokens:
        return (batch, cfg.vision_tokens, cfg.d_model)
    return None


def extra_embed_spec(cfg: ArchConfig, batch: int):
    shape = extra_embed_shape(cfg, batch)
    if shape is None:
        return None
    return jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype))


def make_stub_embeds(key, cfg: ArchConfig, batch: int):
    shape = extra_embed_shape(cfg, batch)
    if shape is None:
        return None
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(
        jnp.dtype(cfg.dtype))
