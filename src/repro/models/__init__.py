from repro.models.transformer import forward, init_lm, lm_loss  # noqa: F401
