"""Minimal pure-function module system.

Parameters live in a *flat dict* keyed by slash-separated paths; a parallel
flat dict maps each key to a tuple of *logical axis names* used by the
sharding rules in ``repro.train.sharding``. Homogeneous transformer stacks are
*stacked* along a leading ``layers`` axis and executed with ``lax.scan``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jax.Array]
Axes = Dict[str, Tuple[str, ...]]


class ParamStore:
    """Collects params + logical axes during model init."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: Params = {}
        self.axes: Axes = {}

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape, axes, init: str = "normal",
              scale: float | None = None, dtype=None) -> jax.Array:
        assert name not in self.params, f"duplicate param {name}"
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = dtype or self.dtype
        if init == "normal":
            # fan-in scaled normal; last contraction dim heuristic
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            s = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
            arr = jax.random.normal(self._next_key(), shape, jnp.float32) * s
        elif init == "zeros":
            arr = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            arr = jnp.ones(shape, jnp.float32)
        elif init == "uniform":
            s = scale if scale is not None else 1.0
            arr = jax.random.uniform(self._next_key(), shape, jnp.float32,
                                     -s, s)
        else:
            raise ValueError(init)
        arr = arr.astype(dtype)
        self.params[name] = arr
        self.axes[name] = tuple(axes)
        return arr


def subtree(params: Params, prefix: str) -> Params:
    """Slice a flat dict to keys under ``prefix/`` (prefix stripped)."""
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(dt)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN: down( silu(x@gate) * (x@up) )."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", silu(g) * u, w_down)


def group_norm_heads(x: jax.Array, gamma: jax.Array, eps: float = 64e-5):
    """Per-head group norm used by RWKV6 output; x: (..., H, hd)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)).astype(dt)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    pos = np.arange(length)[:, None]
    inv = np.exp(-np.log(10000.0) * (np.arange(0, dim, 2) / dim))[None, :]
    tab = np.zeros((length, dim), np.float32)
    tab[:, 0::2] = np.sin(pos * inv)
    tab[:, 1::2] = np.cos(pos * inv)
    return jnp.asarray(tab)
