"""Mixture-of-Experts FFN with capacity-based gather/scatter routing.

TPU adaptation: instead of a dense one-hot dispatch einsum (O(T^2) FLOPs at
high expert counts) we build an (E, C) token-index buffer with a cumsum
position assignment and use pure gathers/scatters, so expert FLOPs stay
O(capacity_factor x active FLOPs). Experts are sharded over the ``model``
mesh axis ("expert" logical axis); in fsdp mode d_ff additionally shards
over ``data``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamStore, silu


def init_moe(store: ParamStore, prefix: str, cfg: ArchConfig, stack: int = 0):
    """stack>0: leading `layers` axis for lax.scan."""
    E, d, ff = cfg.moe.num_experts, cfg.d_model, cfg.d_ff
    lead = (stack,) if stack else ()
    lax_ = ("layers",) if stack else ()
    store.param(f"{prefix}/router", lead + (d, E), lax_ + ("embed", "expert"),
                scale=0.02)
    store.param(f"{prefix}/w_gate", lead + (E, d, ff),
                lax_ + ("expert", "embed", "ff"))
    store.param(f"{prefix}/w_up", lead + (E, d, ff),
                lax_ + ("expert", "embed", "ff"))
    store.param(f"{prefix}/w_down", lead + (E, ff, d),
                lax_ + ("expert", "ff", "embed"))


def apply_moe(p, x: jax.Array, cfg: ArchConfig):
    """x: (B, T, d) -> (out (B, T, d), aux_loss scalar).

    Routing/capacity is computed independently per example (B is the sharded
    axis), keeping dispatch local to the data shard.
    """
    B, T, d = x.shape
    E, k, cf = cfg.moe.num_experts, cfg.moe.top_k, cfg.moe.capacity_factor
    C = max(1, int(T * k * cf / E))

    logits = jnp.einsum("btd,de->bte", x, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (B,T,E) fp32
    top_w, top_e = jax.lax.top_k(probs, k)                   # (B,T,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # flatten the k routes into the token axis: (B, T*k)
    routes = top_e.reshape(B, T * k)
    route_w = top_w.reshape(B, T * k)
    onehot = jax.nn.one_hot(routes, E, dtype=jnp.int32)      # (B,T*k,E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot            # position in expert
    pos = jnp.sum(pos_all * onehot, axis=-1)                 # (B,T*k)
    keep = pos < C

    # scatter token indices into the (E*C) dispatch buffer (dropped -> clipped,
    # masked out at combine time)
    token_idx = jnp.tile(jnp.arange(T * k) // k, (B, 1))     # source token
    dest = routes * C + jnp.where(keep, pos, C * E)          # OOB when dropped
    buf = jnp.zeros((B, E * C), jnp.int32)
    buf = jax.vmap(lambda b, dst, src: b.at[dst].set(src, mode="drop"))(
        buf, dest, token_idx)

    gathered = jnp.take_along_axis(
        x, buf[..., None].clip(0, T - 1), axis=1)            # (B, E*C, d)
    gx = gathered.reshape(B, E, C, d)

    # expert SwiGLU, experts sharded over the model axis
    g = jnp.einsum("becd,edf->becf", gx, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", gx, p["w_up"])
    y = jnp.einsum("becf,efd->becd", silu(g) * u, p["w_down"])
    y = y.reshape(B, E * C, d)

    # combine: each route gathers its slot back, weighted, drop-masked
    slot = (routes * C + pos).clip(0, E * C - 1)             # (B,T*k)
    back = jnp.take_along_axis(y, slot[..., None], axis=1)   # (B,T*k,d)
    w = (route_w * keep).astype(back.dtype)
    out = jnp.sum(back.reshape(B, T, k, d) * w.reshape(B, T, k, 1), axis=2)

    # Switch-style load-balance aux loss
    frac_routed = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_routed * mean_prob) * cfg.moe.router_aux_loss
    return out.astype(x.dtype), aux
