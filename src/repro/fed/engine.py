"""Unified federated execution engine (paper Algorithms 1 & 3).

``FLEngine`` is the single round-runner behind every FL reproduction in this
repo (Figs. 5-8 benchmarks and the plug-and-play example). One jit'd round
function is
assembled from three pluggable pieces, each resolved by string key through
the registries in ``repro.fed.registry`` (the extension seam the
declarative ``ExperimentSpec`` API builds on):

1. **Client scheduler** (``SCHEDULERS``) — how the K clients' local
   training is mapped onto the device:

   * ``"vmap"``   — all K clients batched in one ``jax.vmap`` (the original
     runtime). Peak *transient* memory is O(K·M): every client's tau-step
     local-SGD working set (activations, gradients, the per-client g_tilde
     stack) is live at once.
   * ``"chunked"`` — ``jax.lax.scan`` over blocks of at most ``chunk_size``
     clients, ``vmap`` only within a block. Peak transient memory is
     O(chunk·M), which is what unlocks K >> 100 cohorts: the persistent LBG
     bank still scales with K, but the round working set no longer does.
     The actual block size is the largest divisor of K not exceeding
     ``chunk_size`` (never more memory than requested, no wasted compute);
     when K is near-prime and that divisor would be tiny, the engine keeps
     ``chunk_size`` and zero-weight pads the last block instead.
   * ``"sharded"`` — the chunked layout with each block additionally
     mapped over the named 2-D ``(clients, model)`` FL mesh via
     ``shard_map`` (``FLConfig.mesh`` spec — ``None``/``int n``/``[c, m]``
     — resolved through ``launch.mesh.make_fl_mesh``). The chunk's client
     axis shards over ``clients`` (per-device transient memory
     O(chunk·M / c), all clients of a chunk training concurrently — the
     scale axis for 512+ client cohorts); with ``m > 1`` the sparse LBG
     bank, the Algorithm-1 decision, and the aggregation carry
     additionally shard their block rows over ``model`` (per-device bank
     bytes O(K·k_frac·M / (c·m)) — the memory axis for the >=34B archs,
     where the look-back bank dominates).

   * ``"buffered"`` — FedBuff-style buffered *asynchronous* aggregation on
     the chunked layout: stragglers are latency, not absence. A per-client
     latency model (``FLConfig.latency`` / ``latency_kw`` —
     ``repro.fed.latency``; delays drawn per round from the dedicated
     fault stream, so the async replay is seed-exact) routes each
     dispatched client's sparse ``(idx, val)·(w·gscale)`` payload into a
     bounded staleness buffer (one in-flight slot per client) instead of
     the participation fold; the commutative block-layout aggregation
     carry folds each payload in the round it *arrives*, its
     dispatch-round weight discounted by the model's staleness weight
     (``1/(1+s)^alpha``, where-gated to exactly 1.0 at ``s == 0``).
     Per-client compute heterogeneity rides the batch dict as a
     variable-``tau`` vector (reserved key ``"_tau"``): slow clients run
     fewer local steps rather than vanish. Wire/uplink bytes are
     accounted in the *arrival* round (delivery-time CommLedger).
     With ``latency="none"`` and no dropout the plan degenerates to
     dispatch == deliver == mask with zero staleness, and the round —
     weights, fold order, metrics, banks — is bit-for-bit equal to
     ``"chunked"`` (tier-1 tested). Requires the sparse aggregation
     contract (top-k store, ``fused_kernels`` not False); composes with
     every aggregator rule (staleness-aware weighting reaches the robust
     rules through the weight vector) and every wire codec (the buffer
     stores payloads in their wire layout).

   All schedulers accumulate the server aggregate through the engine's
   *aggregator* with the *same* strictly sequential per-client ``lax.scan``
   (carry += w_k * g_k, k = 0..K-1), so their float addition order is
   identical and vmap/chunked (and sharded on a 1-device mesh) produce
   bit-for-bit equal params and metrics on the same seed (tested in
   ``tests/test_engine.py`` / ``tests/test_sharded_scheduler.py``); a
   multi-device sharded round only reassociates the final psum
   (fp32-tolerance equal, identical uplink accounting). A scheduler is a
   factory ``(cfg, num_clients) -> obj`` with ``chunk``/``pad`` ints plus
   ``prepare_batch(host_arrays)`` and
   ``run(client_fn, agg, params, batch, lbg, resid, w, maskf)`` (``agg``
   is the aggregator below); an optional ``layout_banks(bank)`` hook lets
   it own the state banks' physical layout.

   ``FLConfig.aggregator`` selects the *server rule* the aggregator
   implements. ``"mean"`` (default) is the streaming fold above —
   bit-for-bit the pre-robustness histories. Any robust rule from
   ``repro.fed.robust`` (``trimmed_mean`` / ``coordinate_median`` /
   ``geometric_median``, extendable via ``@register_aggregator``)
   switches every scheduler into **collect mode**: the per-client
   payloads (dense g_tilde, or the sparse (idx, val) + gscale
   scalar-round payload, densified server-side) ride the scan outputs
   into a (K, ...) stack and the rule reduces them in one weighted
   cross-client estimate — O(K·M) peak, the honest price of a median.

   Client faults come from ``repro.fed.attacks``: ``FLConfig.attack`` /
   ``attack_frac`` / ``attack_kw`` flag a fixed seed-derived Byzantine
   cohort whose payloads are corrupted inside ``client_fn`` *before* the
   uplink pipeline and LBG store step (so a recycle round's rho is
   poisoned too); ``label_flip`` corrupts the cohort's data at engine
   build instead. ``dropout_frac`` injects straggler dropout through the
   participation-mask path. Byzantine flags and per-round attack seeds
   ride the batch dict under reserved ``"_byz"``/``"_atk_*"`` keys (so
   they inherit every scheduler's batch layout and the prefetcher's H2D
   overlap); all fault randomness draws from a dedicated stream, so
   clean runs are bit-for-bit unchanged and attacked runs replay
   deterministically under the same seed.

   The aggregator is how the per-round hot path does work proportional to
   what the round transmits (``FLConfig.fused_kernels``):

   * ``DenseAggregator`` — the legacy path: every client materializes a
     dense params-shaped g_tilde and the carry adds O(M) per client.
   * ``SparseTopKAggregator`` — sparse scalar-round aggregation for the
     top-k stores: each client contributes only its (idx, val) payload,
     scatter-added into a per-leaf block-layout accumulator with the
     client's ``w_k * gscale_k`` folded in (``gscale`` = rho on a recycle
     round, 1 on a full round), still strictly sequentially (deterministic
     order). The chunked/sharded inner loop drops from O(chunk·M) to
     O(chunk·k_frac·M) flops and HBM traffic — on the scalar-heavy rounds
     the paper demonstrates, the aggregation cost tracks the ~1-float
     uplink instead of the model size. Full rounds are bit-for-bit equal
     to the dense path (same values, same order); scalar rounds fold
     w·rho before the scatter (fp32-tolerance). ``fused_kernels=False``
     restores the dense path exactly.

   Host-side, the round loop is double-buffered: ``RoundPrefetcher`` (used
   by ``FLEngine.run`` and ``run_experiment``) prepares round t+1's
   batches/mask on a daemon thread while the device executes round t —
   the ROADMAP's "async round overlap" item. The prefetch thread is the
   rng's only consumer while active, so the draw stream (and therefore
   every number in the history) is identical to the synchronous path.

2. **LBGStore** (``LBG_STORES``) — how each client's look-back gradient is
   stored and how Algorithm 1's accept/recycle decision is made:

   * ``DenseLBGStore`` (``"dense"``, legacy alias ``"full"``) —
     paper-faithful dense pytree bank, one params-shaped LBG per client
     (wraps ``repro.core.lbgm.lbgm_client_step``).
   * ``TopKLBGStore`` (``"topk"``) — sparse (indices, values) bank at
     ``k_frac`` density (wraps ``lbgm_topk_client_step``); the bank shrinks
     from O(K·M) to O(K·k_frac·M), the enabling step for large-model
     cohorts.
   * ``NullLBGStore`` (``"null"``) — vanilla FL (``use_lbgm=False``):
     gradients pass through, every round is a full round.
   * ``ShardedTopKLBGStore`` (``"topk-sharded"``) — the top-K bank laid
     out for the sharded scheduler: rows live on the device that trains
     their client (client-axis sharding via ``layout_banks``), and the
     accept/recycle decision reuses ``topk_step_core`` through
     ``repro.core.lbgm_sharded.make_local_topk_step`` — fully
     device-local, so LBGM adds zero cross-device traffic.
   * ``HostTopKLBGStore`` (``"topk-host"``) — the top-K bank kept
     host-resident as NumPy: ``run_round`` switches to an out-of-core
     chunk loop where a ``_HostBankStreamer`` daemon thread uploads
     chunk c+1's bank/batch rows while the device computes chunk c and
     writes the updated rows back on the same thread. Per-round device
     bank bytes are O(chunk·k_frac·M) regardless of K — 100k-client
     cohorts on fixed device memory, bit-for-bit equal to ``"topk"``.

   **Hierarchical tiers** (``FLConfig.tiers`` — ``repro.fed.hierarchy``)
   interpose edge->region->global aggregation behind the aggregator
   seam: a ``HierarchicalAggregator`` folds per-edge partial carries
   alongside the inner streaming aggregator's untouched flat carry (so
   the global update stays bit-for-bit the flat fold), and the
   CommLedger attributes per-tier wire bytes (edge links carry the
   sparse client payloads; each active edge/region forwards one dense
   partial-carry model upstream). Collect-mode robust rules keep their
   flat numerics — for them the tier map is accounting-only.

   **Checkpointing** (``FLConfig.ckpt_every`` / ``ckpt_path`` —
   ``repro.checkpoint.ckpt``): every N completed rounds the engine
   atomically persists params, banks, residuals, the buffered in-flight
   slots, all host rng streams, and the CommLedger;
   ``FLEngine.run(resume=True)`` / ``repro.fed.run --resume`` continue
   the run bit-for-bit (the prefetch producer snapshots its post-draw
   host state with every round, so the checkpoint cut is exact even
   with rounds queued ahead).

   A store implements ``init(params, K)``, ``client_step(grad, lbg_k)`` and
   ``full_round_cost(base_cost, stats)``; new storage schemes (e.g.
   quantized or host-offloaded LBGs) plug in via
   ``@register_lbg_store("name")`` on a ``cfg -> store`` factory.

3. **Uplink pipeline** (``COMPRESSORS``) — base compressor + error feedback
   composed behind ``repro.compression.make_uplink_pipeline`` (top-K /
   ATOMO / SignSGD, paper P3/P4), applied to the accumulated stochastic
   gradient before the LBGM decision.

Uplink accounting follows the paper's metric of floating-point parameters
shared per worker: a scalar (recycle) round uploads exactly 1 float, a full
round pays the pipeline/store cost.

On top of that float count, the **wire codec** (``FLConfig.codec`` /
``codec_kw`` — ``repro.comm.wire``) decides how those floats are encoded on
the wire and accounts the real bytes: ``"none"`` ships fp32 (bit-for-bit
the pre-codec histories), ``"delta_idx"`` varint-compresses the sparse
payload indices, ``"int8"``/``"fp8"`` stochastically quantize the values
with one power-of-two scale per block row. Encoding happens in
``client_fn`` *after* the uplink pipeline (the bank stores the
server-decodable values, so recycle rounds stay deployment-faithful);
decoding happens at the aggregator seam — for quantized streaming
aggregation the dequantize is fused into the scatter-accumulate
(:class:`SparseCodecAggregator` -> ``kernels.ops.lbgm_dequant_accum``), so
no fp32 payload stack is ever materialized. Per-client ``wire_bytes`` ride
the scheduler outputs next to ``uplink`` and land in the
:class:`~repro.comm.accounting.CommLedger` (savings vs vanilla fp32 dense).
"""
from __future__ import annotations

import queue
import threading
import warnings
import weakref
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import ckpt as ckpt_lib
from repro.comm.accounting import CommLedger
from repro.comm.wire import WIRE_KEY, codec_rng, make_codec
from repro.compression import make_uplink_pipeline
from repro.core import lbgm as lbgm_lib
from repro.core.lbgm_sharded import (_SM_KW, _shard_map,
                                     bank_model_partition,
                                     make_local_topk_step,
                                     make_mesh_topk_step)
from repro.core.tree_math import tree_size, tree_zeros_like
from repro.fed.attacks import (BYZ_KEY, STALE_KEY, fault_rng, make_attack,
                               select_byzantine)
from repro.fed.flconfig import FLConfig  # noqa: F401  (re-export)
from repro.fed.hierarchy import HierarchicalAggregator, make_tier_map
from repro.fed.latency import make_latency
from repro.fed.registry import (LBG_STORES, SCHEDULERS, register_lbg_store,
                                register_scheduler)
from repro.fed.robust import (CollectDenseAggregator,
                              CollectSparseAggregator,
                              ScalarMedianSparseAggregator, make_robust_rule)
from repro.kernels.ops import lbgm_dequant_accum
from repro.kernels.ref import lbgm_dequant_accum_ref

#: reserved batch key: per-client local-step budgets (the buffered
#: scheduler's compute heterogeneity) — stripped before the SGD scan
TAU_KEY = "_tau"


def resolve_fused_kernels(cfg: FLConfig) -> bool:
    """Pallas half of the ``FLConfig.fused_kernels`` knob.

    ``None`` = auto: compiled Mosaic kernels on TPU only — everywhere else
    the Pallas interpreter would be slower than the XLA fallback, so auto
    turns them off. ``True`` forces them on (interpret mode off-TPU, used
    by the fused-path tests); ``False`` is the legacy 3-pass XLA path.
    """
    if cfg.fused_kernels is None:
        return jax.default_backend() == "tpu"
    return bool(cfg.fused_kernels)


# ------------------------------------------------------------- LBG stores

def _null_stats():
    return lbgm_lib.LBGMStats(
        sin2=jnp.ones((), jnp.float32), rho=jnp.zeros((), jnp.float32),
        sent_scalar=jnp.zeros((), bool),
        uplink_floats=jnp.zeros((), jnp.float32),
        grad_sq_norm=jnp.zeros((), jnp.float32))


class NullLBGStore:
    """Vanilla FL: no LBG bank, every round is a full round."""

    def init(self, params, num_clients: int):
        return {}

    def client_step(self, grad, lbg_k):
        return grad, lbg_k, _null_stats()

    def full_round_cost(self, base_cost, stats):
        return base_cost


class DenseLBGStore:
    """Paper-faithful Algorithm 1: one dense params-shaped LBG per client.

    ``fused=True`` routes the decision's three O(M) reductions through the
    one-pass Pallas projection kernel (``kernels.ops.lbgm_projection``,
    batched over the schedulers' client vmap axis).
    """

    def __init__(self, delta_threshold: float, fused: bool = False):
        self.delta = delta_threshold
        self.fused = fused

    def init(self, params, num_clients: int):
        return jax.tree.map(
            lambda p: jnp.zeros((num_clients,) + p.shape, p.dtype), params)

    def client_step(self, grad, lbg_k):
        return lbgm_lib.lbgm_client_step(grad, lbg_k, self.delta,
                                         fused=self.fused)

    def full_round_cost(self, base_cost, stats):
        # full rounds ship whatever the uplink pipeline produced
        return base_cost


class TopKLBGStore:
    """Sparse (idx, val) LBG bank at k_frac density (paper App. C.1).

    ``fused=True`` fuses the decision's three dense passes per leaf
    (gather, ||g||^2, block top-k) into one Pallas pass
    (``kernels.ops.lbgm_sparse_decision``). ``sparse_client_step`` /
    ``make_aggregator`` implement the sparse scalar-round aggregation
    contract (see the module docstring): the step emits only the (idx,
    val) payload + a gscale scalar, and the matching
    :class:`SparseTopKAggregator` scatter-adds it into the round
    aggregate — no per-client dense g_tilde anywhere.
    """

    def __init__(self, delta_threshold: float, k_frac: float = 0.1,
                 fused: bool = False):
        self.delta = delta_threshold
        self.k_frac = k_frac
        self.fused = fused

    def init(self, params, num_clients: int):
        proto = lbgm_lib.init_topk_lbg(params, self.k_frac)
        return jax.tree.map(
            lambda x: jnp.zeros((num_clients,) + x.shape, x.dtype), proto)

    def client_step(self, grad, lbg_k):
        return lbgm_lib.lbgm_topk_client_step(grad, lbg_k, self.delta,
                                              self.k_frac, fused=self.fused)

    def sparse_client_step(self, grad, lbg_k):
        """((send, gscale), new_lbg, stats) — no dense scatter."""
        return lbgm_lib.lbgm_topk_client_step(grad, lbg_k, self.delta,
                                              self.k_frac, sparse_out=True,
                                              fused=self.fused)

    def make_aggregator(self, params):
        return SparseTopKAggregator(params, self.k_frac)

    def full_round_cost(self, base_cost, stats):
        # the sparse-transmission cost model (values + block-local indices)
        # lives in core/lbgm.py; reuse its number rather than re-deriving
        return stats.uplink_floats


class ShardedTopKLBGStore(TopKLBGStore):
    """Sparse (idx, val) bank laid out for mesh sharding.

    Same bank shapes and cost model as :class:`TopKLBGStore`, but the
    accept/recycle decision goes through
    ``repro.core.lbgm_sharded.make_mesh_topk_step`` — the decision body of
    the 2-D ``(clients, model)`` mesh:

    * along the *client* axis the bank rows live on the device that trains
      their client (placed by ``ShardedScheduler.layout_banks``), so the
      per-client decision adds zero cross-device traffic;
    * with ``n_model > 1`` each leaf's block rows additionally shard over
      the *model* axis (where ``nb`` divides — see
      ``bank_model_partition``): every model rank gathers/top-ks only its
      own rows of the gradient's global block layout and the three
      decision scalars are psum-reduced over ``model`` before the
      accept/recycle branch. Per-device bank bytes drop to
      O(K·k_frac·M / (n_clients·n_model)).

    On ``n_model == 1`` this is exactly ``make_local_topk_step`` (no psum
    at all), numerically identical to ``TopKLBGStore`` — the two stores
    are interchangeable bit-for-bit on any scheduler.
    """

    def __init__(self, delta_threshold: float, k_frac: float = 0.1,
                 fused: bool = False, n_model: int = 1,
                 model_axis: str = "model"):
        super().__init__(delta_threshold, k_frac, fused=fused)
        self.n_model = int(n_model)
        self.model_axis = model_axis
        # dense g_tilde path (fused_kernels=False, dense aggregation):
        # always the full-leaf device-local step — with n_model > 1 the
        # banks stay model-replicated and every rank decides identically
        self._step = make_local_topk_step(delta_threshold, k_frac,
                                          fused=fused)
        self._sparse_step = make_mesh_topk_step(
            delta_threshold, k_frac, n_model=self.n_model,
            model_axis=model_axis, sparse_out=True, fused=fused)

    def client_step(self, grad, lbg_k):
        return self._step(grad, lbg_k)

    def sparse_client_step(self, grad, lbg_k):
        return self._sparse_step(grad, lbg_k)

    def blocked_sparse_step(self, layouts):
        """Decision step for ``model_sharding="auto"``: gradients arrive
        already in block-row layout, pre-sliced to the calling model
        rank's rows (the scheduler's nested shard_map boundary does the
        TP-layout -> block-row reshard once). ``layouts`` is the global
        ``name -> (nb, block, kb)`` tree; the decision math, psums, and
        uplink accounting are exactly ``sparse_client_step``'s."""
        return make_mesh_topk_step(
            self.delta, self.k_frac, n_model=self.n_model,
            model_axis=self.model_axis, sparse_out=True, fused=self.fused,
            pre_blocked=True, layouts=layouts)

    def bank_model_partition(self, params) -> Dict[str, bool]:
        """name -> whether that leaf's bank block rows shard over the
        model axis (the scheduler's placement and this store's decision
        slicing share the one rule in ``core.lbgm_sharded``)."""
        return bank_model_partition(params, self.k_frac, self.n_model)


class HostTopKLBGStore(TopKLBGStore):
    """Sparse (idx, val) bank kept host-resident (``"topk-host"``).

    Same decision math, cost model, and aggregator as
    :class:`TopKLBGStore` — the per-client step is *bit-for-bit* the
    in-memory store's — but ``init`` allocates the (Kp, nb, kb) bank as
    NumPy on the host instead of a device array. The engine detects
    ``host_resident`` and switches ``run_round`` into the out-of-core
    chunk loop: a :class:`_HostBankStreamer` daemon thread uploads chunk
    ``c+1``'s bank rows (and batch rows) host->device while the device
    computes chunk ``c``, and writes chunk ``c``'s updated rows back to
    the host array on the same thread — so per-round *device* bank bytes
    are O(chunk * k_frac * M) regardless of K. That is what unlocks
    100k-client cohorts on a fixed-memory device (ROADMAP open item 2);
    the chunked scheduler's in-memory path keeps the whole O(K * k_frac
    * M) bank live on device.

    Requires ``scheduler="chunked"`` with streaming aggregation and no
    error-feedback residual (validated at FLConfig construction / engine
    build); histories are bit-for-bit equal to ``"topk"`` on the same
    seed (tier-1 tested).
    """

    #: engine marker: run_round streams bank chunks from host memory
    host_resident = True

    def init(self, params, num_clients: int):
        proto = lbgm_lib.init_topk_lbg(params, self.k_frac)
        return jax.tree.map(
            lambda x: np.zeros((num_clients,) + tuple(x.shape),
                               np.dtype(x.dtype)), proto)


def _lbg_kw(cfg: FLConfig) -> dict:
    """User lbg_kw with an actionable error for engine-reserved keys
    (a raw collision would surface as a cryptic TypeError from the store
    constructor, against this repo's validated-config convention)."""
    kw = dict(cfg.lbg_kw or {})
    if "fused" in kw:
        raise ValueError(
            "FLConfig.lbg_kw: 'fused' is engine-controlled — set "
            "FLConfig.fused_kernels instead of passing it to the store")
    for reserved in ("n_model", "model_axis"):
        if reserved in kw:
            raise ValueError(
                f"FLConfig.lbg_kw: {reserved!r} is engine-controlled — "
                "the model axis comes from FLConfig.mesh ([clients, "
                "model]), not from store kwargs")
    return kw


register_lbg_store("null", lambda cfg: NullLBGStore())
register_lbg_store("dense", aliases=("full",))(
    lambda cfg: DenseLBGStore(cfg.delta_threshold,
                              fused=resolve_fused_kernels(cfg)))
register_lbg_store("topk")(
    lambda cfg: TopKLBGStore(cfg.delta_threshold,
                             fused=resolve_fused_kernels(cfg),
                             **_lbg_kw(cfg)))
register_lbg_store("topk-sharded")(
    lambda cfg: ShardedTopKLBGStore(cfg.delta_threshold,
                                    fused=resolve_fused_kernels(cfg),
                                    n_model=cfg.mesh_model_dim,
                                    **_lbg_kw(cfg)))
register_lbg_store("topk-host")(
    lambda cfg: HostTopKLBGStore(cfg.delta_threshold,
                                 fused=resolve_fused_kernels(cfg),
                                 **_lbg_kw(cfg)))


def make_lbg_store(cfg: FLConfig):
    """Resolve the configured LBG storage scheme through ``LBG_STORES``."""
    key = "null" if not cfg.use_lbgm else cfg.resolved_lbg_variant
    return LBG_STORES.get(key)(cfg)


# ------------------------------------------------------------ aggregators

class DenseAggregator:
    """Legacy accumulation: dense fp32 params-shaped carry, strictly
    sequential weighted sum over each client's dense g_tilde (O(M) flops
    and HBM traffic per client, whatever the round transmitted)."""

    def init(self, params):
        return tree_zeros_like(params, jnp.float32)

    def accumulate(self, acc, w, gt_stack):
        return _seq_weighted_sum(acc, w, gt_stack)

    def finalize(self, acc):
        return acc


class SparseTopKAggregator:
    """Sparse scalar-round aggregation for the top-k LBG stores.

    The carry is a per-leaf ``(nb, block)`` fp32 accumulator in the same
    block layout as the sparse bank. Each client k contributes exactly its
    transmitted payload: ``(w_k * gscale_k) * send.val`` scatter-added at
    ``send.idx`` — O(k_frac·M) per client instead of the dense path's
    O(M) scatter + O(M) add. Accumulation stays a strictly sequential
    per-client ``lax.scan`` (deterministic order; top-k indices are unique
    within a block row, so the scatter-add itself is order-free), and
    ``finalize`` reshapes back to the params layout once per round.

    Equivalence to :class:`DenseAggregator` (the oracle, kept behind
    ``fused_kernels=False``): bit-for-bit on full rounds (``gscale == 1``
    makes every addend ``w_k * val`` — same values, same order; untouched
    positions only ever add exact zeros), fp32-reassociation-tolerance on
    scalar rounds (``w_k * rho_k`` is folded before the multiply with the
    LBG values instead of after).
    """

    payload_keys = ("idx", "val")

    def __init__(self, params, k_frac: float):
        self._layout = {
            name: (leaf.shape, int(leaf.size))
            + lbgm_lib._block_layout(int(leaf.size), k_frac)[:2]
            for name, leaf in params.items()}

    def init(self, params):
        return {name: jnp.zeros((nb, block), jnp.float32)
                for name, (_, _, nb, block) in self._layout.items()}

    def accumulate(self, acc, w, out):
        send, gscale = out            # leaves (C, nb, kb); gscale (C,)

        def body(a, x):
            w_k, send_k, s_k = x
            coeff = w_k * s_k

            def upd(ai, sk):
                # gather-modify-scatter rather than scatter-add: the
                # update is then the same `a + where(w>0, c*v, 0)`
                # expression the dense path accumulates with, so XLA's
                # FMA contraction applies identically and full rounds stay
                # bit-for-bit equal to DenseAggregator (a scatter-add
                # rounds the multiply separately — off by 1 ulp). Sound
                # because top-k indices are unique within a block row.
                # The w_k > 0 gate mirrors _seq_weighted_sum: phantom pad
                # clients may carry NaN values/gscale.
                rows = jnp.arange(ai.shape[0])[:, None]
                cur = ai[rows, sk["idx"]]
                new = cur + jnp.where(w_k > 0, coeff * sk["val"], 0.0)
                return ai.at[rows, sk["idx"]].set(new)

            return {name: upd(a[name], send_k[name]) for name in a}, None

        acc, _ = jax.lax.scan(body, acc, (w, send, gscale))
        return acc

    def finalize(self, acc):
        return {name: acc[name].reshape(-1)[:size].reshape(shape)
                for name, (shape, size, _, _) in self._layout.items()}


class SparseCodecAggregator(SparseTopKAggregator):
    """Streaming aggregation of QUANTIZED sparse payloads.

    Same strictly sequential per-client fold, layout, and finalize as
    :class:`SparseTopKAggregator`, but each client's payload arrives in
    the wire layout ``{idx, val (int8/fp8), scale}`` and the dequantize
    (widen + per-block-row scale multiply) happens *inside* the
    accumulate: one ``kernels.ops.lbgm_dequant_accum`` Pallas pass per
    leaf per chunk when ``fused=True``, the ``lbgm_dequant_accum_ref``
    XLA scan otherwise (bit-identical op order — the interpreted kernel
    is validated against exactly that oracle). Either way the fp32
    (C, nb, kb) payload stack is never materialized — the values widen
    on the fly as they scatter into the fp32 accumulator.
    """

    payload_keys = ("idx", "val", "scale")

    def __init__(self, params, k_frac: float, fused: bool = False):
        super().__init__(params, k_frac)
        self._accum = lbgm_dequant_accum if fused else lbgm_dequant_accum_ref

    def accumulate(self, acc, w, out):
        send, gscale = out   # idx/val (C, nb, kb); scale (C, nb, 1)
        return {name: self._accum(acc[name], w, gscale, sk["idx"],
                                  sk["val"], sk["scale"])
                for name, sk in send.items()}


def make_aggregator(cfg: FLConfig, store, params, codec):
    """Resolve the round aggregation strategy for ``(cfg, store)``.

    Two orthogonal choices meet here. The *payload* (sparse vs dense):
    sparse scalar-round payloads whenever the store supports them and
    ``fused_kernels`` is not explicitly ``False`` (pure XLA, so unlike the
    Pallas kernels it pays off on every backend). The *rule*
    (``cfg.aggregator``, resolved through the AGGREGATORS registry):
    ``"mean"`` keeps the streaming fold above — the exact legacy code
    path, bit-for-bit with pre-robustness histories — while every robust
    rule (trimmed_mean / coordinate_median / geometric_median / ...)
    switches the schedulers into *collect* mode: a median cannot be
    folded one client at a time, so the per-client payload stacks (dense
    g_tilde or sparse (idx, val) + gscale) are collected across chunks
    and reduced once per round (see ``repro.fed.robust``).

    The *codec* is the third axis: a lossy codec hands sparse payloads to
    the fused dequant-accumulate (:class:`SparseCodecAggregator`) on the
    streaming path, and hands the collect adapters its ``decode_leaf`` /
    ``payload_keys`` so the robust rules see fp32 values again. The
    ``scalar_median`` rule additionally demands the sparse payload
    structure itself — it never densifies, so it has no dense fallback.
    """
    rule = make_robust_rule(cfg)
    sparse = (cfg.fused_kernels is not False
              and hasattr(store, "make_aggregator"))
    if getattr(rule, "scalar_structured", False) and not sparse:
        raise ValueError(
            f"aggregator={cfg.aggregator!r} exploits the sparse "
            "scalar-round payload structure and has no dense fallback — "
            "use a top-k LBG store (lbg_variant='topk'/'topk-sharded') "
            "and leave fused_kernels unset or True")
    decode = codec.decode_leaf if codec.lossy else None
    pk = codec.payload_keys
    if getattr(rule, "streaming", False):
        if sparse:
            if codec.lossy:
                return SparseCodecAggregator(
                    params, store.k_frac,
                    fused=resolve_fused_kernels(cfg)), True
            return store.make_aggregator(params), True
        return DenseAggregator(), False
    if getattr(rule, "scalar_structured", False):
        return ScalarMedianSparseAggregator(
            rule, params, store.k_frac, decode=decode,
            payload_keys=pk), True
    if sparse:
        return CollectSparseAggregator(rule, params, store.k_frac,
                                       decode=decode,
                                       payload_keys=pk), True
    return CollectDenseAggregator(rule), False


# ------------------------------------------------------------- schedulers

def pick_chunk(num_clients: int, chunk_size: int) -> int:
    """Actual scan-block size for the chunked scheduler.

    Prefer the largest divisor of K that fits in chunk_size — same memory
    bound, zero phantom-client compute. Only when K is so indivisible that
    the best divisor is under half the requested size (e.g. prime K) do we
    keep chunk_size and pay for a zero-weight padded tail block instead.
    """
    c = min(chunk_size, num_clients)
    d = max(x for x in range(1, c + 1) if num_clients % x == 0)
    return d if d >= max(1, c // 2) else c


def _seq_weighted_sum(acc, w, gt_stack):
    """acc + sum_k w[k] * gt_stack[k], accumulated strictly sequentially.

    Shared by both schedulers so the addition order (and therefore the
    float rounding) is identical regardless of how clients were batched.
    """
    def body(a, x):
        w_k, gt_k = x
        # the w_k > 0 gate (not just w_k *) keeps zero-weight clients out
        # even when their gradient is non-finite — phantom pad clients run
        # the user's loss_fn on all-zero batches, which may produce NaNs
        # that 0 * NaN would otherwise leak into the aggregate
        return jax.tree.map(
            lambda ai, gi: ai + jnp.where(
                w_k > 0, w_k * gi.astype(jnp.float32), 0.0), a, gt_k), None
    out, _ = jax.lax.scan(body, acc, (w, gt_stack))
    return out


def _keep_sampled(maskf, new, old):
    """Unsampled clients keep their previous per-client state."""
    return jax.tree.map(
        lambda n, o: jnp.where(
            maskf.reshape((-1,) + (1,) * (n.ndim - 1)) > 0, n, o), new, old)


@register_scheduler("vmap")
class VmapScheduler:
    """All K clients in one vmap; O(K·M) transient working set."""

    def __init__(self, cfg: FLConfig, num_clients: int):
        self.chunk, self.pad = num_clients, 0

    def prepare_batch(self, stacked: Dict[str, np.ndarray]):
        return stacked  # leaves stay (K, tau, b, ...)

    def run(self, client_fn, agg, params, batch, lbg, resid, w, maskf):
        gt, new_lbg, new_res, loss, uplink, scalar, wire = jax.vmap(
            lambda b, l, r: client_fn(params, b, l, r))(batch, lbg, resid)
        if getattr(agg, "collect", False):
            # robust rules need the whole per-client stack at once — vmap
            # already has it in hand
            out = agg.reduce(w, gt)
        else:
            out = agg.finalize(agg.accumulate(agg.init(params), w, gt))
        return (out, _keep_sampled(maskf, new_lbg, lbg),
                _keep_sampled(maskf, new_res, resid), loss, uplink, scalar,
                wire)


@register_scheduler("chunked")
class ChunkedScheduler:
    """lax.scan over blocks of `chunk` clients; O(chunk·M) transient set.

    The LBG / residual banks ride in the scan *carry* and are updated
    in place per chunk via dynamic_update_slice (rather than stacked as
    scan outputs), so XLA never materializes a second O(K·M) bank buffer.
    The engine allocates banks padded to the chunk grid (K + pad rows).
    """

    def __init__(self, cfg: FLConfig, num_clients: int):
        self.num_clients = num_clients
        self.chunk = pick_chunk(num_clients, cfg.chunk_size)
        self.pad = (-num_clients) % self.chunk

    def prepare_batch(self, stacked: Dict[str, np.ndarray]):
        """(K, tau, b, ...) -> (n_chunks, chunk, tau, b, ...), padded
        host-side so the device scan consumes the argument buffer
        directly (no device-side copy). The pad rows are written into one
        preallocated buffer (no extra concatenate copy of the K rows)."""
        chunk, pad = self.chunk, self.pad

        def to_chunks(x):
            if pad:
                padded = np.zeros((x.shape[0] + pad,) + x.shape[1:],
                                  x.dtype)
                padded[:x.shape[0]] = x
                x = padded
            return x.reshape((x.shape[0] // chunk, chunk) + x.shape[1:])
        return {k: to_chunks(v) for k, v in stacked.items()}

    def run(self, client_fn, agg, params, batch, lbg, resid, w, maskf):
        K, chunk, pad = self.num_clients, self.chunk, self.pad
        if pad:
            w = jnp.concatenate([w, jnp.zeros(pad, w.dtype)])
            maskf = jnp.concatenate([maskf, jnp.zeros(pad, maskf.dtype)])
        Kp = K + pad
        n_chunks = Kp // chunk
        slice_at = lambda t, i: jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk), t)
        update_at = lambda t, u, i: jax.tree.map(
            lambda x, v: jax.lax.dynamic_update_slice_in_dim(
                x, v, i * chunk, axis=0), t, u)

        collect = getattr(agg, "collect", False)

        def chunk_body(carry, xs):
            acc, lbg_bank, res_bank = carry
            i, b_c, w_c, m_c = xs
            l_c, r_c = slice_at(lbg_bank, i), slice_at(res_bank, i)
            gt, nl, nr, loss, uplink, scalar, wire = jax.vmap(
                lambda b, l, r: client_fn(params, b, l, r))(b_c, l_c, r_c)
            if collect:
                # a robust rule cannot fold a median chunk-by-chunk: stack
                # the raw per-client payloads as scan outputs instead
                # (O(Kp·payload) — the documented collect-mode memory)
                ys = (loss, uplink, scalar, wire, gt)
            else:
                acc = agg.accumulate(acc, w_c, gt)
                ys = (loss, uplink, scalar, wire)
            lbg_bank = update_at(lbg_bank, _keep_sampled(m_c, nl, l_c), i)
            res_bank = update_at(res_bank, _keep_sampled(m_c, nr, r_c), i)
            return (acc, lbg_bank, res_bank), ys

        init = (jnp.zeros(()) if collect else agg.init(params), lbg, resid)
        (acc, new_lbg, new_res), ys = jax.lax.scan(
            chunk_body, init,
            (jnp.arange(n_chunks), batch, w.reshape(n_chunks, chunk),
             maskf.reshape(n_chunks, chunk)))
        if collect:
            loss, uplink, scalar, wire, gt = ys
            out = agg.reduce(w, jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), gt))
        else:
            loss, uplink, scalar, wire = ys
            out = agg.finalize(acc)
        return (out, new_lbg, new_res, loss.reshape(Kp)[:K],
                uplink.reshape(Kp)[:K], scalar.reshape(Kp)[:K],
                wire.reshape(Kp)[:K])


@register_scheduler("buffered")
class BufferedScheduler(ChunkedScheduler):
    """FedBuff-style buffered asynchronous aggregation (chunked layout).

    Three stages per round, one jit'd function:

    1. **compute** — the standard chunked ``lax.scan`` runs every client
       (local SGD + attack + pipeline + Algorithm-1 decision + codec
       encode); state banks update only under the *dispatch* mask (a
       client busy with an in-flight payload neither recomputes its bank
       nor re-dispatches). Payloads ride the scan outputs like collect
       mode — they go to the buffer, not straight into the fold.
    2. **buffer write** — each dispatching client overwrites its single
       in-flight slot (payload leaves in wire layout, gscale, its
       dispatch-round weight, and uplink/scalar/wire accounting) via a
       ``where`` on the dispatch mask; everyone else's slot is carried
       bit-unchanged.
    3. **delivery fold** — the round's *delivered* slots are folded with
       weights ``w0 * disc(stale) * deliver``, normalized over the
       delivered cohort. Streaming rules fold chunk-by-chunk inside a
       scan with the exact per-chunk ``accumulate`` structure
       :class:`ChunkedScheduler` compiles (same expressions, same
       strictly sequential order — the zero-latency bit-for-bit
       guarantee); collect rules get the full (Kp, ...) stack, so
       staleness-aware weighting reaches mean / geometric_median /
       scalar_median through the one weight vector they already honor.

    Delivered uplink/scalar/wire are reported in the arrival round; a
    round that delivers nothing reports zeros (the ledger guards its
    savings ratios against a zero-vanilla round).
    """

    #: engine marker: run via run_buffered with the host delivery plan
    delivery_weighted = True

    def run(self, client_fn, agg, params, batch, lbg, resid, w, maskf):
        raise TypeError(
            "BufferedScheduler aggregates through run_buffered(...); the "
            "engine threads the delivery plan and staleness buffer")

    def run_buffered(self, client_fn, agg, params, batch, lbg, resid,
                     buf, w0, dispatchf, deliverf, stalef, disc):
        K, chunk, pad = self.num_clients, self.chunk, self.pad
        dzp, w0p = dispatchf, w0
        if pad:
            z = jnp.zeros(pad, jnp.float32)
            dzp = jnp.concatenate([dispatchf, z])
            w0p = jnp.concatenate([w0, z])
        Kp = K + pad
        n_chunks = Kp // chunk
        slice_at = lambda t, i: jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk), t)
        update_at = lambda t, u, i: jax.tree.map(
            lambda x, v: jax.lax.dynamic_update_slice_in_dim(
                x, v, i * chunk, axis=0), t, u)

        def chunk_body(carry, xs):
            lbg_bank, res_bank = carry
            i, b_c, m_c = xs
            l_c, r_c = slice_at(lbg_bank, i), slice_at(res_bank, i)
            gt, nl, nr, loss, uplink, scalar, wire = jax.vmap(
                lambda b, l, r: client_fn(params, b, l, r))(b_c, l_c, r_c)
            lbg_bank = update_at(lbg_bank, _keep_sampled(m_c, nl, l_c), i)
            res_bank = update_at(res_bank, _keep_sampled(m_c, nr, r_c), i)
            return (lbg_bank, res_bank), (gt, loss, uplink, scalar, wire)

        (new_lbg, new_res), ys = jax.lax.scan(
            chunk_body, (lbg, resid),
            (jnp.arange(n_chunks), batch, dzp.reshape(n_chunks, chunk)))
        gt, loss, uplink, scalar, wire = ys
        flat = lambda x: x.reshape((-1,) + x.shape[2:])
        send, gscale = jax.tree.map(flat, gt)
        loss, uplink, scalar, wire = (flat(loss), flat(uplink),
                                      flat(scalar), flat(wire))

        def gate(new, old):
            d = dzp.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(d > 0, new.astype(old.dtype), old)
        nbuf = {
            "send": jax.tree.map(gate, send, buf["send"]),
            "gscale": gate(gscale, buf["gscale"]),
            "w0": gate(w0p, buf["w0"]),
            "uplink": gate(uplink, buf["uplink"]),
            "scalar": gate(scalar.astype(jnp.float32), buf["scalar"]),
            "wire": gate(wire, buf["wire"]),
        }

        # delivery weights: stored dispatch-round weight x staleness
        # discount x delivered flag, normalized with the same (K,)
        # expression round_fn applies to the synchronous schedulers —
        # under the zero-latency plan (dispatch == deliver == mask,
        # stale == 0, disc(0) == 1.0 exactly, undelivered slots zeroed
        # by the flag) this reproduces the chunked weights bit-for-bit.
        wd = nbuf["w0"][:K] * disc(stalef) * deliverf
        wn = wd / jnp.maximum(jnp.sum(wd), 1e-12)
        wnp = jnp.concatenate([wn, jnp.zeros(pad, wn.dtype)]) if pad \
            else wn
        if getattr(agg, "collect", False):
            out = agg.reduce(wnp, (nbuf["send"], nbuf["gscale"]))
        else:
            def fold_body(acc, xs):
                w_c, send_c, gs_c = xs
                return agg.accumulate(acc, w_c, (send_c, gs_c)), None

            acc, _ = jax.lax.scan(
                fold_body, agg.init(params),
                (wnp.reshape(n_chunks, chunk),
                 jax.tree.map(lambda x: x.reshape(
                     (n_chunks, chunk) + x.shape[1:]), nbuf["send"]),
                 nbuf["gscale"].reshape(n_chunks, chunk)))
            out = agg.finalize(acc)
        dlv = lambda x: x[:K] * deliverf
        return (out, new_lbg, new_res, nbuf, loss[:K],
                dlv(nbuf["uplink"]), dlv(nbuf["scalar"]),
                dlv(nbuf["wire"]))


def pick_sharded_chunk(num_clients: int, chunk_size: int, n_dev: int) -> int:
    """Scan-block size for the sharded scheduler.

    Same policy as :func:`pick_chunk` with one extra constraint: the block
    must split evenly over the ``n_dev`` mesh devices (shard_map requires
    ``chunk % n_dev == 0``). ``n_dev == 1`` reduces to ``pick_chunk``
    exactly — that shared layout is half of what makes the 1-device sharded
    round bit-identical to the chunked one.
    """
    if n_dev == 1:
        return pick_chunk(num_clients, chunk_size)
    # cap at min(chunk_size, K) like pick_chunk (never more memory than
    # requested, no chunk mostly made of phantom clients), then round down
    # to the mesh grid — but never below n_dev, the smallest legal block
    c = max(min(chunk_size, num_clients) // n_dev * n_dev, n_dev)
    divs = [x for x in range(n_dev, c + 1, n_dev) if num_clients % x == 0]
    if divs and divs[-1] >= max(n_dev, c // 2):
        return divs[-1]
    return c


@register_scheduler("sharded")
class ShardedScheduler(ChunkedScheduler):
    """Chunked layout with each block mapped over the 2-D ``(clients,
    model)`` FL mesh: the same (n_chunks, chunk) ``lax.scan``, but every
    chunk's clients train data-parallel under ``shard_map`` along the
    ``clients`` axis (``FLConfig.mesh``, resolved by
    ``launch.mesh.make_fl_mesh``), so the per-DEVICE transient set is
    O(chunk·M / n_clients_dev); with a 2-D spec (``mesh=[c, m]``) the
    LBGM decision and the sparse banks/aggregator carry additionally
    shard their block rows over the ``model`` axis, dropping per-device
    bank bytes to O(K·k_frac·M / (c·m)) for the >=34B-style configs where
    the look-back bank dominates memory.

    State banks are stored ``(n_chunks, chunk, ...)`` with the chunk's
    client axis sharded over the mesh — and, for a model-sharded sparse
    bank (see :meth:`configure_store` / ``bank_model_partition``), the
    block-row axis over ``model`` — so the per-chunk bank slice/update
    and the LBGM accept/recycle decision read only device-local rows; the
    cross-device traffic per chunk is one fp32 psum of the weighted
    aggregate along ``clients`` (plus loss/uplink scalars) and, when
    model-sharded, the three decision scalars psum'd along ``model``
    inside the store's step.

    Device 0 of the client axis folds the scan carry into its local
    strictly-sequential accumulation, so on a (1, 1) mesh the addition
    order — and therefore the whole round history — is bit-identical to
    ``ChunkedScheduler`` (and an ``(n, 1)`` mesh is bit-identical to the
    pre-2-D 1-D client mesh); on larger meshes the psum reassociates the
    sum across devices, the documented fp32-tolerance difference (uplink
    accounting is still exact: the global block layout is mesh-shape
    independent).
    """

    AXIS = "clients"
    MODEL_AXIS = "model"

    def __init__(self, cfg: FLConfig, num_clients: int):
        from repro.launch.mesh import make_fl_mesh
        self.mesh = make_fl_mesh(cfg.mesh, client_axis=self.AXIS,
                                 model_axis=self.MODEL_AXIS)
        self.n_client_dev = int(self.mesh.shape[self.AXIS])
        self.n_model = int(self.mesh.shape[self.MODEL_AXIS])
        self.n_dev = int(self.mesh.devices.size)
        self.num_clients = num_clients
        self.chunk = pick_sharded_chunk(num_clients, cfg.chunk_size,
                                        self.n_client_dev)
        self.pad = (-num_clients) % self.chunk
        # set by configure_store when the LBG bank model-shards: per-leaf
        # {name: bool} for the sparse bank's block rows, mirrored onto the
        # aggregator carry; None = everything model-replicated (the 1-D
        # client-mesh behavior)
        self._msharded: Optional[Dict[str, bool]] = None
        # set by bind_model_axes (model_sharding="auto"): per-leaf param
        # PartitionSpecs resolved from the model component's logical axes,
        # the matching NamedShardings (the engine places/keeps params with
        # them), and the global name -> (nb, block, kb) block layouts the
        # auto chunk body reshapes gradients into. None = "replicate".
        self._auto_specs: Optional[Dict[str, P]] = None
        self._layouts = None
        self.param_shardings: Optional[Dict[str, NamedSharding]] = None

    # ----------------------------------------------------- model binding
    def configure_store(self, store, sparse_agg: bool, params) -> None:
        """Record which bank/aggregator leaves shard over ``model``.

        Model sharding is on only when all three hold: a 2-D mesh was
        requested, the engine picked sparse aggregation (the dense
        g_tilde path cannot assemble leaves across model ranks), and the
        store knows how to partition its bank
        (``store.bank_model_partition``). Otherwise the model axis has
        extent >= 1 but everything on it is replicated — bit-for-bit the
        pre-2-D behavior.
        """
        if (self.n_model > 1 and sparse_agg
                and hasattr(store, "bank_model_partition")):
            self._msharded = store.bank_model_partition(params)

    def bind_model_axes(self, axes_tree, params, layouts) -> None:
        """Switch this scheduler into ``model_sharding="auto"``.

        ``axes_tree`` is the model component's logical-axis pytree (the
        ``train.sharding.params_shardings`` input — e.g. ``("embed",
        "heads")``); it is resolved against this mesh into per-leaf
        ``PartitionSpec``s with ``param_pspec`` in "replicated" mode (the
        FL mesh has no fsdp "data" axis — only the model-parallel axes
        shard, and only where the mesh extent divides). ``layouts`` is the
        engine-computed global ``name -> (nb, block, kb)`` block layout
        the auto chunk body (and the store's ``blocked_sparse_step``)
        share, so the gradient reshape and the decision slicing agree by
        construction.

        Leaves with a ``vocab`` logical axis (embedding table, lm_head)
        are sharded along their ``embed`` (d_model) dim instead of vocab:
        vocab sharding makes the token lookup and the CE label pick
        gathers *along the sharded dim*, whose backward is a scatter the
        SPMD partitioner refuses to split inside a partial-auto region
        (``Check failed: sharding.IsManualSubgroup``). d_model sharding
        keeps those gathers device-local — the only collective left is
        the contraction psum GSPMD inserts.
        """
        from repro.train.sharding import param_pspec
        missing = sorted(set(params) - set(axes_tree))
        if missing:
            raise ValueError(
                f"model_sharding='auto': the model component's axes tree "
                f"is missing leaves {missing} — every param leaf needs a "
                "logical-axis tuple (see train.sharding.params_shardings)")
        m = self.mesh.shape.get(self.MODEL_AXIS, 1)

        def leaf_spec(name):
            axes = tuple(axes_tree[name])
            shape = params[name].shape
            if "vocab" in axes:
                out, used = [], False
                for logical, dim in zip(axes, shape):
                    if logical == "embed" and not used and dim % m == 0:
                        out.append(self.MODEL_AXIS)
                        used = True
                    else:
                        out.append(None)
                return P(*out)
            return param_pspec(axes, shape, "replicated", self.mesh)

        self._auto_specs = {name: leaf_spec(name) for name in params}
        self._layouts = layouts
        self.param_shardings = {
            name: NamedSharding(self.mesh, spec)
            for name, spec in self._auto_specs.items()}

    def _bank_leaf_spec(self, path, chunk_leading: bool):
        """PartitionSpec for one bank leaf; ``path`` is the jax key path
        ((name,) for a dense bank leaf, (name, 'idx'|'val') for a sparse
        one). ``chunk_leading=True`` adds the scan's n_chunks axis."""
        ms = self._msharded or {}
        name = path[0].key if path else None
        axes = (self.AXIS,)
        if len(path) == 2 and ms.get(name):
            axes = (self.AXIS, self.MODEL_AXIS)
        return P(None, *axes) if chunk_leading else P(*axes)

    def _payload_specs(self, agg, lbg):
        """Collect-stack specs for the sparse payload leaves.

        Same client/model placement as the bank rows the payload came
        from, but with the codec's leaf structure (``agg.payload_keys``):
        a quantized payload carries a per-block-row ``scale`` leaf the
        bank does not have, so the bank's spec tree cannot be reused
        verbatim when the bank model-shards."""
        ms = self._msharded or {}
        pk = getattr(agg, "payload_keys", ("idx", "val"))
        spec = lambda name: (P(self.AXIS, self.MODEL_AXIS) if ms.get(name)
                             else P(self.AXIS))
        return {name: {k: spec(name) for k in pk} for name in lbg}

    # ------------------------------------------------------ bank placement
    def layout_banks(self, bank):
        """(Kp, ...) bank -> (n_chunks, chunk, ...), client axis sharded
        (block-row axis too, for a model-sharded sparse bank).

        The round scan indexes whole chunks (axis 0), so sharding axis 1
        over the mesh puts every chunk's bank rows exactly where its
        clients train — per-chunk slice/update never moves bank bytes
        between devices."""
        def f(path, x):
            x = x.reshape((x.shape[0] // self.chunk, self.chunk)
                          + x.shape[1:])
            if self.n_dev > 1:
                x = jax.device_put(x, NamedSharding(
                    self.mesh, self._bank_leaf_spec(path, True)))
            return x
        return jax.tree_util.tree_map_with_path(f, bank)

    def run(self, client_fn, agg, params, batch, lbg, resid, w, maskf):
        K, chunk, pad, ax = self.num_clients, self.chunk, self.pad, self.AXIS
        if pad:
            w = jnp.concatenate([w, jnp.zeros(pad, w.dtype)])
            maskf = jnp.concatenate([maskf, jnp.zeros(pad, maskf.dtype)])
        Kp = K + pad
        n_chunks = Kp // chunk
        rep, cl = P(), P(ax)
        # per-leaf specs: sparse-bank leaves may shard block rows over the
        # model axis; the aggregator carry mirrors the same partition (its
        # (nb, block) leaves hold the rows the local sends scatter into)
        ms = self._msharded
        lbg_specs = jax.tree_util.tree_map_with_path(
            lambda path, _: self._bank_leaf_spec(path, False), lbg) \
            if ms else cl
        acc_specs = {name: P(self.MODEL_AXIS) if on else rep
                     for name, on in ms.items()} if ms else rep

        auto = self._auto_specs is not None
        if auto:
            # model_sharding="auto": the per-chunk client compute runs as
            # plain GSPMD — no enclosing shard_map — with the params
            # constrained to the component's resolved tensor-parallel
            # specs and the batch constrained along `clients`, so the
            # vmapped local-SGD forward/backward partitions over the full
            # 2-D mesh. (An enclosing partial-auto shard_map is NOT an
            # option: `lax.scan` bodies — the layer stack, tau local-SGD,
            # chunked CE — trip the SPMD partitioner's manual-subgroup
            # checks, as do top_k/scatter.) The Algorithm-1 decision +
            # aggregation then run in ONE fully-manual shard_map over
            # (clients, model): its in_specs hand each rank exactly the
            # bank/accumulator/block rows the "replicate" path owns, and
            # GSPMD implements the one TP-layout -> block-row reshard of
            # the round at that boundary. Banks and the aggregation carry
            # keep the exact "replicate" placement and the global block
            # layout is unchanged, so uplink accounting is identical;
            # histories match within fp32 reassociation tolerance.
            pre, post = client_fn
            mesh, MX = self.mesh, self.MODEL_AXIS
            pspecs, layouts, msd = self._auto_specs, self._layouts, ms or {}
            blk_spec = lambda name: (P(ax, MX, None) if msd.get(name)
                                     else P(ax))

            def manual_fn(acc_i, blk, l_, cost_, thru_, w_, m_):
                gt, nl_, uplink, scalar, wire = jax.vmap(post)(
                    blk, l_, cost_, thru_)
                # identical carry seeding + clients psum to the
                # "replicate" local_chunk below, so per-chunk accumulation
                # order matches ChunkedScheduler
                first = jax.lax.axis_index(ax) == 0
                acc_i = jax.tree.map(
                    lambda a: jnp.where(first, a, 0.0), acc_i)
                acc_i = jax.lax.psum(agg.accumulate(acc_i, w_, gt), ax)
                return (acc_i, _keep_sampled(m_, nl_, l_), uplink, scalar,
                        wire)

            def sharded_chunk(acc, p, b, l, r, w_c, m_c):
                cst = lambda v, s: jax.lax.with_sharding_constraint(
                    v, NamedSharding(mesh, s))
                p = jax.tree.map(cst, p, pspecs)
                b = jax.tree.map(lambda v: cst(v, P(ax)), b)
                asg, nr, loss, cost, thru = jax.vmap(
                    lambda bb, rr: pre(p, bb, rr))(b, r)
                loss = cst(loss, P(ax))
                blocked = {
                    name: jax.vmap(
                        lambda g, nb=layouts[name][0],
                        blk=layouts[name][1]:
                        lbgm_lib._to_blocks(g, nb, blk))(asg[name])
                    for name in asg}
                manual = _shard_map(
                    manual_fn, mesh=mesh,
                    in_specs=(acc_specs,
                              {name: blk_spec(name) for name in blocked},
                              lbg_specs, cl,
                              jax.tree.map(lambda _: cl, thru), cl, cl),
                    out_specs=(acc_specs, lbg_specs, cl, cl, cl),
                    **_SM_KW)
                acc, nl, uplink, scalar, wire = manual(
                    acc, blocked, l, cost, thru, w_c, m_c)
                return (acc, nl, _keep_sampled(m_c, nr, r), loss, uplink,
                        scalar, wire)

        collect = getattr(agg, "collect", False)
        if auto:
            pass
        elif collect:
            # robust collect mode: no carry to fold — each device emits its
            # local clients' raw payloads, stitched to the global (chunk,
            # ...) stack by the out specs (sparse (idx, val) payloads keep
            # the bank's client/model placement; the weighted reduce runs
            # once per round on the global stack, outside shard_map)
            def local_chunk(p, b, l, r, w_c, m_c):
                gt, nl, nr, loss, uplink, scalar, wire = jax.vmap(
                    lambda bb, ll, rr: client_fn(p, bb, ll, rr))(b, l, r)
                return (gt, _keep_sampled(m_c, nl, l),
                        _keep_sampled(m_c, nr, r), loss, uplink, scalar,
                        wire)

            if getattr(agg, "sparse", False):
                gt_specs = ((self._payload_specs(agg, lbg) if ms else cl),
                            cl)
            else:
                gt_specs = cl
            sharded_chunk = _shard_map(
                local_chunk, mesh=self.mesh,
                in_specs=(rep, cl, lbg_specs, cl, cl, cl),
                out_specs=(gt_specs, lbg_specs, cl, cl, cl, cl, cl),
                **_SM_KW)
        else:
            def local_chunk(acc, p, b, l, r, w_c, m_c):
                gt, nl, nr, loss, uplink, scalar, wire = jax.vmap(
                    lambda bb, ll, rr: client_fn(p, bb, ll, rr))(b, l, r)
                # client-device 0 seeds its local accumulation with the
                # scan carry, so each chunk folds into the aggregate in the
                # same strictly sequential order as ChunkedScheduler; the
                # psum is the identity on a 1-device client axis (the carry
                # — dense params-shaped or sparse block-layout, per the
                # aggregator — is replicated along `clients`; model-sharded
                # carry leaves hold disjoint rows per model rank, never
                # summed over model)
                first = jax.lax.axis_index(ax) == 0
                acc = jax.tree.map(lambda a: jnp.where(first, a, 0.0), acc)
                acc = jax.lax.psum(agg.accumulate(acc, w_c, gt), ax)
                return (acc, _keep_sampled(m_c, nl, l),
                        _keep_sampled(m_c, nr, r), loss, uplink, scalar,
                        wire)

            sharded_chunk = _shard_map(
                local_chunk, mesh=self.mesh,
                in_specs=(acc_specs, rep, cl, lbg_specs, cl, cl, cl),
                out_specs=(acc_specs, lbg_specs, cl, cl, cl, cl, cl),
                **_SM_KW)

        idx_at = lambda t, i: jax.tree.map(
            lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
            t)
        put_at = lambda t, u, i: jax.tree.map(
            lambda x, v: jax.lax.dynamic_update_index_in_dim(x, v, i, 0),
            t, u)

        def chunk_body(carry, xs):
            acc, lbg_bank, res_bank = carry
            i, b_c, w_c, m_c = xs
            l_c, r_c = idx_at(lbg_bank, i), idx_at(res_bank, i)
            if collect:
                gt, nl, nr, loss, uplink, scalar, wire = sharded_chunk(
                    params, b_c, l_c, r_c, w_c, m_c)
                ys = (loss, uplink, scalar, wire, gt)
            else:
                acc, nl, nr, loss, uplink, scalar, wire = sharded_chunk(
                    acc, params, b_c, l_c, r_c, w_c, m_c)
                ys = (loss, uplink, scalar, wire)
            return ((acc, put_at(lbg_bank, nl, i), put_at(res_bank, nr, i)),
                    ys)

        init = (jnp.zeros(()) if collect else agg.init(params), lbg, resid)
        (acc, new_lbg, new_res), ys = jax.lax.scan(
            chunk_body, init,
            (jnp.arange(n_chunks), batch, w.reshape(n_chunks, chunk),
             maskf.reshape(n_chunks, chunk)))
        if collect:
            loss, uplink, scalar, wire, gt = ys
            out = agg.reduce(w, jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), gt))
        else:
            loss, uplink, scalar, wire = ys
            out = agg.finalize(acc)
        return (out, new_lbg, new_res, loss.reshape(Kp)[:K],
                uplink.reshape(Kp)[:K], scalar.reshape(Kp)[:K],
                wire.reshape(Kp)[:K])


def make_scheduler(cfg: FLConfig, num_clients: int):
    """Resolve the configured client scheduler through ``SCHEDULERS``."""
    return SCHEDULERS.get(cfg.scheduler)(cfg, num_clients)


# ------------------------------------------------------------- engine

class FLEngine:
    """loss_fn(params, batch_dict) -> (loss, metrics). Data is a list of
    per-client dicts of numpy arrays (see repro.fed.partition).

    ``model_axes`` is the model component's optional logical-axis pytree
    (``{name: ("embed", "heads"), ...}`` — the
    ``train.sharding.params_shardings`` input). It is required by — and
    only read under — ``FLConfig.model_sharding="auto"``, where the
    sharded scheduler resolves it against the 2-D mesh so each client's
    local-SGD forward/backward runs tensor-parallel over the ``model``
    axis. ``fed.experiment.build_experiment`` threads it automatically
    from components that return ``(params, loss_fn, axes_tree)``.
    """

    def __init__(self, loss_fn: Callable, params: Dict[str, jax.Array],
                 client_data: List[Dict[str, np.ndarray]], flcfg: FLConfig,
                 model_axes: Optional[Dict] = None):
        self.loss_fn = loss_fn
        self.cfg = flcfg
        self.params = params
        self.model_axes = model_axes
        self.client_data = client_data
        K = flcfg.num_clients
        assert len(client_data) == K
        empty = [k for k, d in enumerate(client_data)
                 if len(next(iter(d.values()))) == 0]
        if empty:
            raise ValueError(
                f"FLEngine: clients {empty} have no training samples; "
                "every client needs >= 1 (a label-skew partition starves "
                "clients when class demand exceeds supply — use more data, "
                "fewer clients, or more classes_per_client)")
        # Byzantine attack + fault injection (repro.fed.attacks): the
        # Byzantine cohort is one fixed round(attack_frac*K) subset for the
        # whole run; data-level attacks corrupt the malicious clients'
        # local shards HERE, before the engine concatenates its one copy
        # of the dataset. Per-round attack noise and dropout_frac
        # straggler faults consume the dedicated fault stream, never the
        # batch/mask rng — a clean run is bit-for-bit unchanged.
        self.attack = make_attack(flcfg)
        self._byz = select_byzantine(K, flcfg.attack_frac, flcfg.seed)
        self._payload_attack = None
        if self.attack is not None:
            if self.attack.level == "data":
                client_data = [
                    self.attack.corrupt(d) if self._byz[k] > 0 else d
                    for k, d in enumerate(client_data)]
                self.client_data = client_data
            else:
                self._payload_attack = self.attack
        self._fault_rng = fault_rng(flcfg.seed)
        # the scheduler owns the scan-block layout (its run/prepare_batch
        # consume it); _chunk/_pad stay mirrored here as the engine's
        # introspection surface — bank padding below and the tier-1 layout
        # assertions read them
        self.sched = make_scheduler(flcfg, K)
        self._chunk, self._pad = self.sched.chunk, self.sched.pad
        sizes = np.array([len(next(iter(d.values())))
                          for d in client_data], np.float64)
        self.weights = jnp.asarray(sizes / sizes.sum(), jnp.float32)
        # per-round batch gathers run against one concatenated copy of the
        # client data (client k's samples live at offset[k]:offset[k]+n_k),
        # so _sample_batches is a single vectorized fancy-index instead of
        # a K-iteration Python loop of per-client gathers + np.stack.
        # client_data is then re-pointed at zero-copy views into the
        # concatenation so the engine holds ONE copy of the dataset.
        self._data_sizes = sizes.astype(np.int64)
        self._data_offsets = np.concatenate(
            [[0], np.cumsum(self._data_sizes[:-1])]).astype(np.int64)
        self._data_cat = {k: np.concatenate([d[k] for d in client_data])
                          for k in client_data[0]}
        self.client_data = [
            {k: v[off:off + n] for k, v in self._data_cat.items()}
            for off, n in zip(self._data_offsets, self._data_sizes)]
        self.store = make_lbg_store(flcfg)
        #: "topk-host": the LBG bank lives in host memory and run_round
        #: streams it chunk-wise (see HostTopKLBGStore / _HostBankStreamer)
        self._host_bank = bool(getattr(self.store, "host_resident", False))
        # wire codec (repro.comm.wire): payload encoding + real-byte
        # accounting. Its per-client seeds come from a dedicated stream —
        # drawn only when the codec is stochastic, so codec="none" leaves
        # the batch/mask rng (and every pre-codec history) untouched.
        self.codec = make_codec(flcfg)
        self._codec_rng = codec_rng(flcfg.seed)
        # aggregation strategy: sparse scalar-round scatter-add when the
        # store supports it and fused_kernels is not explicitly False
        self.agg, self._sparse_agg = make_aggregator(flcfg, self.store,
                                                     params, self.codec)
        # hierarchical tiers (FLConfig.tiers — repro.fed.hierarchy): wrap
        # the streaming aggregator so per-edge partial carries fold
        # alongside the untouched flat carry (finalize stays bit-for-bit
        # the flat fold). Collect-mode rules and lossy-codec payloads
        # cannot decompose over partials — for them the tier map is
        # accounting-only (per-tier ledger rows, identical numerics).
        self.tiers = make_tier_map(flcfg)
        self._tiered_fold = False
        if self.tiers is not None and type(self.agg) in (
                SparseTopKAggregator, DenseAggregator):
            self.agg = HierarchicalAggregator(
                self.agg, self.tiers.edge_ids_padded(K + self._pad),
                self.tiers.n_edges)
            self._tiered_fold = True
        if self._host_bank and getattr(self.agg, "collect", False):
            raise ValueError(
                f"lbg_variant='topk-host' streams bank chunks and folds "
                f"payloads as they arrive, but aggregator="
                f"{flcfg.aggregator!r} runs in collect mode (a full "
                "(K, payload) device stack — exactly the O(K) memory the "
                "host store exists to avoid); use aggregator='mean'")
        if self.codec.lossy and not (
                self._sparse_agg or isinstance(self.store, NullLBGStore)):
            raise ValueError(
                f"codec={flcfg.codec!r} is lossy, but the dense LBGM bank "
                "cannot track the server-decoded values (recycle rounds "
                "would replay unquantized LBGs the server never saw). Use "
                "the sparse payload path (lbg_variant='topk'/'topk-sharded' "
                "with fused_kernels not False) or vanilla FL "
                "(use_lbgm=False)")
        # buffered scheduler (FedBuff-style): latency model, host-side
        # delivery plan state, and — below, once Kp is known — the
        # device-side staleness buffer. Synchronous schedulers skip all
        # of it (attributes stay None, every code path unchanged).
        self._latency = None
        self._buffer = None
        self._tau_vec = None
        if getattr(self.sched, "delivery_weighted", False):
            if not self._sparse_agg:
                raise ValueError(
                    "scheduler='buffered' buffers sparse (idx, val) "
                    "payloads between dispatch and delivery — use "
                    "lbg_variant='topk'/'topk-sharded' and leave "
                    "fused_kernels unset or True")
            self._latency = make_latency(flcfg)
            # host delivery plan: at most one in-flight payload per
            # client; arrival[k] = the round it lands (-1 = idle)
            self._arrival = np.full(K, -1, np.int64)
            self._dispatch_round = np.zeros(K, np.int64)
            self._plan_round = 0
            self._pending_delays = None
            self._tau_vec = self._latency.sample_tau(K, flcfg.tau)
            #: delivered-payload count across the run (wire bytes are
            #: attributed per delivery — see the wire-attribution tests)
            self.n_delivered = 0.0
        # 2-D (clients, model) mesh: the scheduler decides — with the
        # store — which bank/aggregator leaves shard over the model axis,
        # BEFORE the banks are laid out below
        if hasattr(self.sched, "configure_store"):
            self.sched.configure_store(self.store, self._sparse_agg, params)
        # model_sharding="auto": validate the contract, resolve the
        # component's axes tree against the mesh, and place the params
        # model-sharded (their per-device argument buffer becomes the 1/m
        # shard). "replicate" (default) skips all of this — bit-for-bit
        # the pre-knob engine.
        self._auto_layouts = None
        if flcfg.model_sharding == "auto":
            self._setup_model_sharding(params, model_axes)
        # banks are allocated padded to the chunk grid once, up front; the
        # phantom rows stay zero forever (their mask is always 0), so the
        # per-round scan updates them in place with no pad/slice copies
        Kp = K + self._pad
        self.lbg = self.store.init(params, Kp)
        self._pipeline, self._use_ef = make_uplink_pipeline(
            flcfg.compressor, flcfg.compressor_kw, flcfg.error_feedback)
        self.residual = jax.tree.map(
            lambda p: jnp.zeros((Kp,) + p.shape, jnp.float32), params) \
            if self._use_ef else {}
        # a scheduler may own the banks' physical layout (the sharded
        # scheduler reshapes to (n_chunks, chunk, ...) and places the
        # client axis over its mesh); values are unchanged
        if hasattr(self.sched, "layout_banks"):
            self.lbg = self.sched.layout_banks(self.lbg)
            self.residual = self.sched.layout_banks(self.residual)
        if self._latency is not None:
            self._buffer = self._init_buffer(params, Kp)
        if self._host_bank:
            # out-of-core round: one jit'd chunk step (banks/batches
            # arrive per chunk from the streamer thread, donated so the
            # updated chunk reuses the uploaded buffer) + tiny jit'd
            # weight-prep / finalize helpers replicating round_fn's exact
            # expressions. No whole-round jit exists on this path.
            self._round = None
            self._chunk_fn = jax.jit(self._build_host_chunk_fn(),
                                     donate_argnums=(1, 2))
            self._host_prep = jax.jit(self._build_host_prep())
            self._host_final = jax.jit(self._build_host_final())
            self._streamer = _HostBankStreamer(self.lbg, self._chunk)
            # the daemon thread parks on its task queue; close it when
            # the engine is collected so tests building many engines do
            # not leak threads (the finalizer holds only the streamer)
            self._streamer_finalizer = weakref.finalize(
                self, self._streamer.close)
        else:
            # donate the LBG/residual banks (and the staleness buffer):
            # the round's new state reuses the old buffers instead of
            # allocating a second O(K·M) copy
            donate = (1, 2, 3) if self._latency is not None else (1, 2)
            self._round = jax.jit(self._build_round(),
                                  donate_argnums=donate)
        # uplink accounting lives in one place (repro.comm.accounting);
        # run_round records into it and history fields derive from it
        self.ledger = CommLedger()
        self.history: List[Dict[str, float]] = []
        #: post-round host-side state snapshot (rng streams + buffered
        #: delivery plan), captured by whichever thread draws the round —
        #: the consistency cut save_checkpoint persists (see there)
        self._host_snapshot: Optional[dict] = None

    # -------------------------------------------------------------- build
    def _setup_model_sharding(self, params, model_axes):
        """Wire ``model_sharding="auto"`` (called from ``__init__``).

        Every rejection names the fix: auto mode runs the decision and
        aggregation inside a nested manual-over-``model`` region, so it
        only composes with the sparse streaming contract, and the
        compressor pipeline (whose top-k/sign ops would hit model-sharded
        gradients in GSPMD auto-land) must stay off.
        """
        cfg = self.cfg

        def bad(msg):
            raise ValueError(f"model_sharding='auto': {msg}")

        if model_axes is None:
            bad("the model component carries no sharding metadata — only "
                "components returning (params, loss_fn, axes_tree) support "
                "tensor-parallel client compute (the 'lm' component does; "
                "fcn/cnn do not). Pass model_axes to FLEngine or use "
                "model_sharding='replicate'")
        if not hasattr(self.sched, "bind_model_axes"):
            bad(f"scheduler {cfg.scheduler!r} cannot bind model axes; use "
                "the built-in 'sharded' scheduler")
        if getattr(self.agg, "collect", False):
            bad(f"aggregator={cfg.aggregator!r} runs in collect mode, "
                "which stacks per-client payloads across the model axis; "
                "only the streaming 'mean' rule is supported")
        if not (self._sparse_agg
                and hasattr(self.store, "blocked_sparse_step")):
            bad("requires the sparse aggregation contract over the "
                "mesh-aware bank — set lbg_variant='topk-sharded' and "
                "leave fused_kernels unset or True")
        if cfg.compressor != "none":
            bad(f"compressor={cfg.compressor!r} would run its top-k/sign "
                "ops on model-sharded gradients inside the auto region; "
                "only compressor='none' is supported")
        # one global block layout, shared by the scheduler's gradient
        # reshape and the store's blocked decision — mesh-shape
        # independent, so uplink accounting matches "replicate" exactly
        self._auto_layouts = {
            name: lbgm_lib._block_layout(int(p.size), self.store.k_frac)
            for name, p in params.items()}
        self.sched.bind_model_axes(model_axes, params, self._auto_layouts)
        self.params = jax.device_put(params, self.sched.param_shardings)

    def _init_buffer(self, params, Kp):
        """The buffered scheduler's staleness buffer: one in-flight slot
        per (padded) client — payload leaves in the codec's wire layout,
        the payload's gscale, the client's dispatch-round weight, and the
        uplink/scalar/wire accounting scalars reported on delivery."""
        k_frac = self.store.k_frac
        lossy = self.codec.lossy
        val_dt = self.codec.wire_dtype if lossy else jnp.float32
        send = {}
        for name, leaf in params.items():
            nb, _, kb = lbgm_lib._block_layout(int(leaf.size), k_frac)
            sk = {"idx": jnp.zeros((Kp, nb, kb), jnp.int32),
                  "val": jnp.zeros((Kp, nb, kb), val_dt)}
            if "scale" in self.codec.payload_keys:
                sk["scale"] = jnp.ones((Kp, nb, 1), jnp.float32)
            send[name] = sk
        zk = lambda: jnp.zeros(Kp, jnp.float32)
        return {"send": send, "gscale": zk(), "w0": zk(),
                "uplink": zk(), "scalar": zk(), "wire": zk()}

    def _make_client_update(self, hetero_tau: bool = False):
        cfg = self.cfg
        loss_fn = self.loss_fn

        if not hetero_tau:
            def client_update(params, batches):
                """tau local steps; batches: dict leaves (tau, b, ...)."""
                def step(p, bt):
                    (l, _), g = jax.value_and_grad(loss_fn,
                                                   has_aux=True)(p, bt)
                    p2 = jax.tree.map(
                        lambda x, gg: x - cfg.lr * gg.astype(x.dtype),
                        p, g)
                    return p2, (g, l)
                _, (gs, ls) = jax.lax.scan(step, params, batches)
                asg = jax.tree.map(lambda g: jnp.sum(g, 0), gs)
                return asg, jnp.mean(ls)

            return client_update

        def client_update(params, batches, tau_k):
            """Variable-tau local SGD (buffered compute heterogeneity):
            the scan still runs the static ``cfg.tau`` steps — same
            shapes, same jit — but steps ``i >= tau_k`` are masked to
            no-ops (zero gradient, frozen params), so a slow client's
            accumulated update and reported loss cover exactly its
            ``tau_k`` real steps."""
            def step(p, xt):
                i, bt = xt
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p,
                                                                      bt)
                on = (i < tau_k).astype(jnp.float32)
                g = jax.tree.map(lambda gg: gg * on.astype(gg.dtype), g)
                p2 = jax.tree.map(
                    lambda x, gg: x - cfg.lr * gg.astype(x.dtype), p, g)
                return p2, (g, l, on)
            _, (gs, ls, ons) = jax.lax.scan(
                step, params, (jnp.arange(cfg.tau), batches))
            asg = jax.tree.map(lambda g: jnp.sum(g, 0), gs)
            loss = jnp.sum(ls * ons) / jnp.maximum(jnp.sum(ons), 1.0)
            return asg, loss

        return client_update

    def _build_client_halves(self):
        """``client_fn`` split at the decision seam, for the sharded
        scheduler's ``model_sharding="auto"`` path.

        ``pre`` runs in the outer GSPMD auto region (tensor-parallel
        local SGD + attack + uplink pipeline — all elementwise on the
        model-sharded gradients); ``post`` runs inside the nested
        manual-over-``model`` region on pre-sliced block rows (decision +
        codec encode — the ops that cannot live in auto-land). Reserved
        batch keys the decision half still needs (the codec seed) travel
        in a pass-through dict. The composition is semantically
        :meth:`_build_client_fn` restricted to the sparse streaming path,
        the only one auto mode admits.
        """
        store = self.store
        pipeline = self._pipeline
        attack = self._payload_attack
        codec = self.codec
        client_update = self._make_client_update()
        blocked_step = store.blocked_sparse_step(self._auto_layouts)

        def pre(params, batches, resid_k):
            batches = dict(batches)
            byz = batches.pop(BYZ_KEY, None)
            thru = {}
            if WIRE_KEY in batches:
                thru[WIRE_KEY] = batches.pop(WIRE_KEY)
            extras = {k: batches.pop(k) for k in list(batches)
                      if k.startswith("_atk_")}
            asg, loss = client_update(params, batches)
            if attack is not None:
                asg = attack.apply(asg, byz, extras)
            asg, resid_k, cost = pipeline(asg, resid_k)
            return asg, resid_k, loss, cost, thru

        def post(blocked_k, lbg_k, cost, thru):
            gt, lbg_k, stats = blocked_step(blocked_k, lbg_k)
            uplink = jnp.where(stats.sent_scalar, 1.0,
                               store.full_round_cost(cost, stats))
            gt, lbg_k, wire = codec.encode_sparse(gt, lbg_k, stats,
                                                  thru.get(WIRE_KEY))
            return gt, lbg_k, uplink, stats.sent_scalar, wire

        return pre, post

    def _build_client_fn(self):
        pipeline = self._pipeline
        store = self.store
        hetero_tau = self._tau_vec is not None
        client_update = self._make_client_update(hetero_tau)

        sparse = self._sparse_agg
        attack = self._payload_attack
        codec = self.codec
        # the legacy dense-aggregation oracle over a top-k store ships the
        # same conceptual (idx, val) payload as the sparse path, so its
        # wire bytes come from the store's static block layout — the two
        # paths must report identical histories (codec is lossless here:
        # lossy codecs are rejected at __init__ without sparse agg)
        sparse_wire = None
        if not sparse and getattr(store, "k_frac", None) is not None:
            sparse_wire = codec.sparse_layout_bytes(
                [lbgm_lib._block_layout(int(p.size), store.k_frac)[::2]
                 for p in self.params.values()])

        def client_fn(params, batches, lbg_k, resid_k):
            # engine-reserved batch keys (Byzantine flag, per-round attack
            # extras, per-client wire-codec seed) ride the batch dict
            # through every scheduler layout and the prefetcher; strip
            # them before the local-SGD scan
            batches = dict(batches)
            byz = batches.pop(BYZ_KEY, None)
            wire_seed = batches.pop(WIRE_KEY, None)
            tau_k = batches.pop(TAU_KEY, None)
            extras = {k: batches.pop(k) for k in list(batches)
                      if k.startswith("_atk_")}
            if hetero_tau:
                asg, loss = client_update(params, batches, tau_k)
            else:
                asg, loss = client_update(params, batches)
            if attack is not None:
                # the Byzantine client corrupts its accumulated gradient
                # BEFORE the uplink pipeline and the LBGM decision: its
                # bank, accept/recycle choice and payload all follow from
                # the corrupted update, exactly as a protocol-following
                # adversary would produce them
                asg = attack.apply(asg, byz, extras)
            asg, resid_k, cost = pipeline(asg, resid_k)
            # sparse aggregation: gt is the ((idx, val) payload, gscale)
            # pair the SparseTopKAggregator scatter-adds — the dense
            # g_tilde is never materialized
            step = store.sparse_client_step if sparse else store.client_step
            gt, lbg_k, stats = step(asg, lbg_k)
            # scalar rounds upload 1 float; full rounds pay the base cost
            uplink = jnp.where(stats.sent_scalar, 1.0,
                               store.full_round_cost(cost, stats))
            # wire codec: encode the payload the uplink actually ships
            # (and, for lossy codecs, re-point the bank at the values the
            # server will decode) + account the real bytes on the wire
            if sparse:
                gt, lbg_k, wire = codec.encode_sparse(gt, lbg_k, stats,
                                                      wire_seed)
            elif sparse_wire is not None:
                wire = jnp.where(stats.sent_scalar, codec.scalar_bytes,
                                 sparse_wire)
            else:
                gt, wire = codec.encode_dense(gt, uplink, wire_seed)
            return (gt, lbg_k, resid_k, loss, uplink, stats.sent_scalar,
                    wire)

        return client_fn

    def _build_round(self):
        cfg = self.cfg
        auto = getattr(self.sched, "_auto_specs", None) is not None
        client_fn = (self._build_client_halves() if auto
                     else self._build_client_fn())
        sched = self.sched
        aggregator = self.agg
        pshard = self.sched.param_shardings if auto else None

        if self._latency is not None:
            disc = self._latency.staleness_weight

            def round_fn(params, lbg, residual, buf, batch, dispatch,
                         deliver, stale):
                """Buffered delivery-time round. ``dispatch`` /
                ``deliver`` / ``stale`` are the host plan's (K,) vectors
                (see ``_sample_mask``); ``buf`` is the staleness buffer.
                Loss is reported over the round's *computing* (dispatch)
                cohort; uplink/scalar/wire over the *delivered* payloads
                — bytes land in the round they arrive."""
                dispatchf = dispatch.astype(jnp.float32)
                deliverf = deliver.astype(jnp.float32)
                stalef = stale.astype(jnp.float32)
                w0 = self.weights * dispatchf
                wl = w0 / jnp.maximum(jnp.sum(w0), 1e-12)
                (agg_out, new_lbg, new_res, new_buf, losses, uplink,
                 scalar, wire) = sched.run_buffered(
                    client_fn, aggregator, params, batch, lbg, residual,
                    buf, w0, dispatchf, deliverf, stalef, disc)
                new_params = jax.tree.map(
                    lambda p, a: p - cfg.lr * a.astype(p.dtype), params,
                    agg_out)
                metrics = {
                    "loss": jnp.sum(losses * wl),
                    "uplink_floats": jnp.sum(uplink),
                    "frac_scalar": jnp.sum(scalar)
                    / jnp.maximum(jnp.sum(deliverf), 1.0),
                    "wire_bytes": jnp.sum(wire),
                }
                return new_params, new_lbg, new_res, new_buf, metrics

            return round_fn

        def round_fn(params, lbg, residual, batch, mask):
            """batch leaves: scheduler layout (see prepare_batch);
            mask: (K,) participation. In chunked mode the state banks are
            permanently padded to the chunk grid (zero-weight phantom
            clients, always masked out); the scheduler pads the small
            per-round vectors itself."""
            maskf = mask.astype(jnp.float32)
            w = self.weights * maskf
            w = w / jnp.maximum(jnp.sum(w), 1e-12)
            agg, new_lbg, new_res, losses, uplink, scalar, wire = sched.run(
                client_fn, aggregator, params, batch, lbg, residual, w,
                maskf)
            new_params = jax.tree.map(
                lambda p, a: p - cfg.lr * a.astype(p.dtype), params, agg)
            if pshard is not None:
                # keep the updated params on their TP layout round over
                # round (the donated input buffers are reused in place)
                new_params = jax.tree.map(
                    jax.lax.with_sharding_constraint, new_params, pshard)
            metrics = {
                "loss": jnp.sum(losses * w),
                "uplink_floats": jnp.sum(uplink * maskf),
                "frac_scalar": jnp.sum(scalar.astype(jnp.float32) * maskf)
                / jnp.maximum(jnp.sum(maskf), 1.0),
                "wire_bytes": jnp.sum(wire * maskf),
            }
            return new_params, new_lbg, new_res, metrics

        return round_fn

    # ------------------------------------------------ out-of-core (host)
    def _build_host_chunk_fn(self):
        """One chunk of the topk-host round — the body is op-for-op
        :class:`ChunkedScheduler`'s ``chunk_body`` (vmap'd client_fn,
        the aggregator's sequential accumulate, ``_keep_sampled`` bank
        gating), compiled standalone so the only device-resident bank
        state is the active chunk's rows. ``acc`` and ``lbg_c`` are
        donated: the updated chunk reuses the uploaded buffer."""
        client_fn = self._build_client_fn()
        agg = self.agg

        def chunk_fn(params, acc, lbg_c, resid_c, b_c, w_c, m_c):
            gt, nl, nr, loss, uplink, scalar, wire = jax.vmap(
                lambda b, l, r: client_fn(params, b, l, r))(
                    b_c, lbg_c, resid_c)
            acc = agg.accumulate(acc, w_c, gt)
            nl = _keep_sampled(m_c, nl, lbg_c)
            return acc, nl, loss, uplink, scalar, wire

        return chunk_fn

    def _build_host_prep(self):
        """Round weights for the host chunk loop — the same expressions
        (and therefore float rounding) as ``round_fn`` + the chunked
        scheduler's zero-padding."""
        weights = self.weights
        pad, chunk = self._pad, self._chunk

        def prep(mask):
            maskf = mask.astype(jnp.float32)
            w = weights * maskf
            w = w / jnp.maximum(jnp.sum(w), 1e-12)
            wp, mp = w, maskf
            if pad:
                wp = jnp.concatenate([w, jnp.zeros(pad, w.dtype)])
                mp = jnp.concatenate([maskf, jnp.zeros(pad, maskf.dtype)])
            n_chunks = wp.shape[0] // chunk
            return (wp.reshape(n_chunks, chunk),
                    mp.reshape(n_chunks, chunk), w, maskf)

        return prep

    def _build_host_final(self):
        """Params update + round metrics — ``round_fn``'s exact
        expressions over the concatenated per-chunk outputs."""
        cfg = self.cfg
        agg = self.agg

        def final(params, acc, losses, uplink, scalar, wire, w, maskf):
            out = agg.finalize(acc)
            new_params = jax.tree.map(
                lambda p, a: p - cfg.lr * a.astype(p.dtype), params, out)
            metrics = {
                "loss": jnp.sum(losses * w),
                "uplink_floats": jnp.sum(uplink * maskf),
                "frac_scalar": jnp.sum(scalar.astype(jnp.float32) * maskf)
                / jnp.maximum(jnp.sum(maskf), 1.0),
                "wire_bytes": jnp.sum(wire * maskf),
            }
            return new_params, metrics

        return final

    def _run_host_round(self, batch, mask):
        """The topk-host round loop: double-buffered bank streaming.

        The streamer thread uploads chunk ``c+1``'s bank + batch rows
        while the device computes chunk ``c`` (dispatch is async — the
        jit call returns before compute finishes), and the same thread
        writes chunk ``c``'s updated rows back to the host array (its
        ``np.asarray`` is what synchronizes on the chunk's compute).
        Device bank footprint: the in-flight chunks only — independent
        of K.
        """
        K = self.cfg.num_clients
        n_chunks = (K + self._pad) // self._chunk
        w_cs, m_cs, w, maskf = self._host_prep(
            jnp.asarray(mask, jnp.float32))
        acc = self.agg.init(self.params)
        st = self._streamer
        st.begin_round(batch, n_chunks)
        outs = []
        try:
            for c in range(n_chunks):
                lbg_c, b_c = st.get(c)
                acc, nl, loss, uplink, scalar, wire = self._chunk_fn(
                    self.params, acc, lbg_c, {}, b_c, w_cs[c], m_cs[c])
                st.put_writeback(c, nl)
                st.request(c + 2)
                outs.append((loss, uplink, scalar, wire))
        finally:
            # barrier: every write-back has landed, so self.lbg (the
            # host array) is the post-round bank when this returns
            st.finish_round()
        cat = lambda xs: jnp.concatenate(list(xs))[:K]
        loss, uplink, scalar, wire = (cat(x) for x in zip(*outs))
        self.params, metrics = self._host_final(
            self.params, acc, loss, uplink, scalar, wire, w, maskf)
        return metrics

    def host_chunk_device_bytes(self) -> int:
        """Device bytes one streamed bank chunk occupies — the per-round
        device bank envelope is ~2x this (double buffer), independent of
        ``num_clients``."""
        if not self._host_bank:
            raise ValueError("host_chunk_device_bytes: engine does not "
                             "run the topk-host store")
        return int(sum(v.nbytes // v.shape[0]
                       for v in jax.tree.leaves(self.lbg)) * self._chunk)

    # -------------------------------------------------------------- data
    def _sample_batches(self, rng: np.random.RandomState):
        """Per-round client batches, laid out by the scheduler's
        ``prepare_batch`` (vmap: (K, tau, b, ...); chunked:
        (n_chunks, chunk, tau, b, ...), padded host-side).

        The K per-client index draws stay sequential — the rng stream is
        part of the reproducibility contract (identical draws to the
        original per-client loop) — but materialization is ONE vectorized
        fancy-index per data key from the concatenated client data
        straight into the (K, tau, b, ...) buffer: no per-client gather
        loop, no intermediate list + ``np.stack`` copy. This is the host
        half of the round hot path that :class:`RoundPrefetcher` overlaps
        with device execution.
        """
        cfg = self.cfg
        idx = np.empty((cfg.num_clients, cfg.tau, cfg.batch_size), np.int64)
        for k, n in enumerate(self._data_sizes):
            idx[k] = rng.randint(0, n, size=(cfg.tau, cfg.batch_size))
        idx += self._data_offsets[:, None, None]
        stacked = {k: v[idx] for k, v in self._data_cat.items()}
        if self._payload_attack is not None:
            # per-client Byzantine flags (+ any per-round attack extras,
            # drawn from the fault stream — never from ``rng``) ride the
            # batch dict so they inherit the scheduler layout, the H2D
            # staging and the prefetch overlap for free
            stacked[BYZ_KEY] = self._byz
            stacked.update(self._payload_attack.round_extras(
                self._fault_rng, cfg.num_clients))
        if self.codec.stochastic:
            # per-client stochastic-rounding seeds from the dedicated
            # codec stream (never the batch/mask rng): one uint32 per
            # client per round, riding the batch layout like the fault
            # keys above. Deterministic codecs draw nothing — the stream
            # (and the prefetcher's behavior) is bit-for-bit unchanged.
            stacked[WIRE_KEY] = self._codec_rng.randint(
                0, 2 ** 31 - 1, size=cfg.num_clients).astype(np.uint32)
        if self._latency is not None:
            # buffered: this round's per-client delay draws happen HERE —
            # an adaptive attack reads its own delay (STALE_KEY) from the
            # batch dict, and _sample_mask (always called right after
            # this, on both the sync and prefetch paths) consumes the
            # cached vector to build the dispatch/deliver plan. The
            # fault-stream order per round is fixed: attack extras, then
            # delays, then dropout draws — so the async replay is
            # seed-exact.
            d = np.asarray(self._latency.sample_delays(
                self._fault_rng, cfg.num_clients), np.int64)
            self._pending_delays = d
            if self._payload_attack is not None:
                stacked[STALE_KEY] = d.astype(np.float32)
            if self._tau_vec is not None:
                stacked[TAU_KEY] = np.asarray(self._tau_vec, np.int32)
        stacked = self.sched.prepare_batch(stacked)
        if self._host_bank:
            # out-of-core path: batches stay host-side — the bank
            # streamer uploads each chunk's rows next to its bank rows,
            # so device batch bytes are O(chunk), K-independent, and the
            # prefetch thread never stages an O(K) H2D transfer
            return {k: np.asarray(v) for k, v in stacked.items()}
        return {k: jnp.asarray(v) for k, v in stacked.items()}

    def _sample_mask(self, rng: np.random.RandomState) -> np.ndarray:
        """Algorithm-3 participation mask for one round.

        Consumes exactly ``num_clients`` uniforms from ``rng`` when
        ``sample_frac < 1`` (and none otherwise) on EVERY path: the
        empty-cohort fallback reuses the uniforms already in hand (the
        client closest to its sampling threshold) instead of drawing extra
        state, so one unlucky round cannot shift every subsequent round's
        batch/mask stream.
        """
        cfg = self.cfg
        if cfg.sample_frac >= 1.0:
            mask = np.ones(cfg.num_clients)
        else:
            u = rng.rand(cfg.num_clients)
            mask = (u < cfg.sample_frac).astype(np.float64)
            if mask.sum() == 0:
                mask[int(np.argmin(u))] = 1.0
        if cfg.dropout_frac > 0.0:
            # straggler/dropout fault injection rides the participation
            # mask: each sampled client independently fails to report with
            # prob dropout_frac. Draws come from the fault stream (exactly
            # num_clients uniforms per round, sampled or not), so the
            # Algorithm-3 rng stream above is untouched and the fault
            # pattern replays under the same seed.
            d = self._fault_rng.rand(cfg.num_clients)
            dropped = mask * (d >= cfg.dropout_frac)
            if dropped.sum() == 0:
                # an all-straggler round still needs one reporter: revive
                # the sampled client least likely to have dropped (no
                # extra draws — stream invariance, as in the empty-cohort
                # fallback above)
                dropped = np.zeros_like(mask)
                dropped[int(np.argmax(np.where(mask > 0, d, -1.0)))] = 1.0
            mask = dropped
        if self._latency is None:
            return mask
        # buffered: turn the participation mask into a delivery plan.
        # dispatch = sampled & idle (one in-flight slot per client); a
        # dispatched payload arrives `delay` rounds later and is folded,
        # staleness-discounted, in its arrival round. Pure integer host
        # bookkeeping over the already-drawn delays — no extra rng.
        t = self._plan_round
        self._plan_round += 1
        d = self._pending_delays
        if d is None:
            # mask drawn without a preceding _sample_batches (tests /
            # external drivers): draw the delays now — same stream, same
            # per-round order
            d = np.asarray(self._latency.sample_delays(
                self._fault_rng, self.cfg.num_clients), np.int64)
        self._pending_delays = None
        # max-staleness eviction (latency_kw={"max_staleness": s}): an
        # in-flight payload older than s rounds is dropped — its slot
        # frees up and the client may re-dispatch THIS round (the only
        # exit for a straggler drop=True payload parked at NEVER). Pure
        # host bookkeeping; the count lands in CommLedger.n_evicted.
        n_evicted = 0
        s_max = self._latency.max_staleness
        if s_max is not None:
            evict = (self._arrival >= 0) & \
                (t - self._dispatch_round > s_max)
            n_evicted = int(evict.sum())
            self._arrival[evict] = -1
        dispatch = (mask > 0) & (self._arrival < 0)
        self._dispatch_round[dispatch] = t
        self._arrival[dispatch] = t + d[dispatch]
        deliver = self._arrival == t
        stale = np.where(deliver, t - self._dispatch_round, 0)
        self._arrival[deliver] = -1
        return {"mask": mask,
                "dispatch": dispatch.astype(np.float64),
                "deliver": deliver.astype(np.float64),
                "stale": stale.astype(np.float64),
                "n_evicted": float(n_evicted)}

    # -------------------------------------------------------------- run
    def prefetcher(self, rng: np.random.RandomState,
                   depth: int = 2) -> "RoundPrefetcher":
        """Double-buffered host batch prep over ``rng``'s draw stream.

        Pass the returned object to :meth:`run_round` in place of the rng;
        while it is alive it must be the ONLY consumer of ``rng`` (that is
        what keeps the stream identical to the synchronous path). Call
        ``close()`` when done — it stops the thread; the rng has then been
        advanced by up to ``depth`` + 1 prefetched rounds.
        """
        return RoundPrefetcher(self, rng, depth=depth)

    def run_round(self, rng) -> Dict[str, float]:
        """One FL round. ``rng`` is either a ``np.random.RandomState``
        (synchronous host prep) or a :class:`RoundPrefetcher` (batches and
        mask already staged by the prefetch thread — same draw stream)."""
        if isinstance(rng, RoundPrefetcher):
            # the producer thread snapshots its post-draw host state with
            # every item (see _capture_host_state) — holding it here
            # means save_checkpoint always persists the state matching
            # the round that actually ran, even though the prefetch
            # thread has drawn ahead
            batch, mask, snap = rng.next()
            self._host_snapshot = snap
        else:
            batch = self._sample_batches(rng)
            mask = self._sample_mask(rng)
            self._host_snapshot = self._capture_host_state(rng)
        if isinstance(mask, dict):
            # buffered delivery plan: uplink/wire (and the vanilla
            # baseline) are attributed to the round payloads ARRIVE in,
            # so a straggler's bytes land when the server folds them
            plan = mask
            (self.params, self.lbg, self.residual, self._buffer,
             metrics) = self._round(
                self.params, self.lbg, self.residual, self._buffer,
                batch, jnp.asarray(plan["dispatch"], jnp.float32),
                jnp.asarray(plan["deliver"], jnp.float32),
                jnp.asarray(plan["stale"], jnp.float32))
            n_del = float(plan["deliver"].sum())
            self.n_delivered += n_del
            self.ledger.n_evicted += plan.get("n_evicted", 0.0)
            vanilla = n_del * tree_size(self.params)
        elif self._host_bank:
            metrics = self._run_host_round(batch, mask)
            vanilla = float(mask.sum()) * tree_size(self.params)
        else:
            self.params, self.lbg, self.residual, metrics = self._round(
                self.params, self.lbg, self.residual, batch,
                jnp.asarray(mask, jnp.float32))
            vanilla = float(mask.sum()) * tree_size(self.params)
        m = {k: float(v) for k, v in metrics.items()}
        tiers = None
        if self.tiers is not None:
            # per-tier wire attribution: edge links carried this round's
            # client payloads (delivered ones, under the buffered plan);
            # each active edge/region forwards one dense partial carry
            active = (plan["deliver"] if isinstance(mask, dict) else mask)
            tiers = self.tiers.round_bytes(
                active, m["wire_bytes"],
                carry_bytes=4.0 * tree_size(self.params))
        # vanilla wire = dense fp32, 4 bytes per param per participant —
        # the baseline both the float and byte savings are measured from
        self.ledger.record(m["uplink_floats"], vanilla,
                           wire=m["wire_bytes"], vanilla_wire=4.0 * vanilla,
                           tiers=tiers)
        m["total_uplink"] = self.ledger.uplink_floats
        m["vanilla_uplink"] = self.ledger.vanilla_floats
        m["savings"] = self.ledger.savings
        m["total_wire_bytes"] = self.ledger.wire_bytes
        m["wire_savings"] = self.ledger.wire_savings
        self.history.append(m)
        return m

    # engine-level accounting views derive from the ledger — the duplicate
    # hand-rolled counters (and their divergent savings guard) are gone
    @property
    def total_uplink(self) -> float:
        return self.ledger.uplink_floats

    @property
    def vanilla_uplink(self) -> float:
        return self.ledger.vanilla_floats

    # ----------------------------------------------------- checkpointing
    @staticmethod
    def _rng_state(rng: np.random.RandomState) -> dict:
        _, keys, pos, has_gauss, cached = rng.get_state()
        return {"keys": keys.copy(), "pos": np.int64(pos),
                "has_gauss": np.int64(has_gauss),
                "cached": np.float64(cached)}

    @staticmethod
    def _set_rng_state(rng: np.random.RandomState, s: dict) -> None:
        rng.set_state(("MT19937", np.asarray(s["keys"], np.uint32),
                       int(s["pos"]), int(s["has_gauss"]),
                       float(s["cached"])))

    def _capture_host_state(self, rng: np.random.RandomState) -> dict:
        """Post-round snapshot of every host-side stream that feeds the
        round draws: the batch/mask rng, the dedicated fault and codec
        streams, and the buffered delivery-plan state. Captured by
        whichever thread samples the round (the prefetch producer, or
        the sync ``run_round`` caller) right after its draws — that is
        the consistency cut that makes resume bit-for-bit: a prefetcher
        may have drawn several rounds ahead at save time, but the
        snapshot the engine holds always matches the round that actually
        executed, and the thrown-away queued draws are simply re-drawn
        identically from the restored stream.
        """
        s = {"rng": self._rng_state(rng),
             "fault_rng": self._rng_state(self._fault_rng),
             "codec_rng": self._rng_state(self._codec_rng)}
        if self._latency is not None:
            s["arrival"] = self._arrival.copy()
            s["dispatch_round"] = self._dispatch_round.copy()
            s["plan_round"] = np.int64(self._plan_round)
        return s

    def save_checkpoint(self, path: str) -> None:
        """Atomically persist the run state after the last completed
        round: params, LBG/residual banks (host array for topk-host),
        the buffered in-flight slots, all rng streams, the CommLedger,
        and the round history — everything ``restore_checkpoint`` needs
        to continue the run bit-for-bit (see ``FLConfig.ckpt_every``)."""
        if self._host_snapshot is None:
            raise ValueError(
                "save_checkpoint: no completed round to snapshot — run "
                "at least one round first")
        state = {
            "params": self.params,
            "lbg": self.lbg,
            "residual": self.residual,
            "host": self._host_snapshot,
            "ledger": self.ledger.state_dict(),
            "history": self.history,
        }
        if self._buffer is not None:
            state["buffer"] = self._buffer
            state["n_delivered"] = np.float64(self.n_delivered)
        ckpt_lib.save_checkpoint(path, state, metadata={
            "version": 1, "round": len(self.history),
            "config": self.cfg.to_dict()})

    def restore_checkpoint(self, path: str,
                           rng: np.random.RandomState) -> int:
        """Load ``path`` into this engine (built from the SAME FLConfig
        — checked against the checkpoint metadata) and restore ``rng``,
        the caller's batch/mask RandomState that will drive subsequent
        rounds. Device arrays are re-placed onto their current shardings
        (topk-sharded bank placement survives); the topk-host bank is
        restored in place so the streamer thread keeps its reference.
        Returns the number of completed rounds (the index to resume
        from)."""
        tree, meta = ckpt_lib.load_checkpoint(path)
        if meta.get("config") != self.cfg.to_dict():
            raise ValueError(
                "restore_checkpoint: checkpoint was written under a "
                "different FLConfig — rebuild the engine with the "
                f"original config. Checkpoint config: {meta.get('config')}")

        def _like(cur, new):
            return jax.device_put(np.asarray(new).astype(cur.dtype),
                                  getattr(cur, "sharding", None))

        self.params = jax.tree.map(_like, self.params, tree["params"])
        if self._host_bank:
            def copy(dst, src):
                dst[...] = np.asarray(src).astype(dst.dtype)
            jax.tree.map(copy, self.lbg, tree.get("lbg", {}))
        elif "lbg" in tree:
            self.lbg = jax.tree.map(_like, self.lbg, tree["lbg"])
        if "residual" in tree:
            self.residual = jax.tree.map(_like, self.residual,
                                         tree["residual"])
        if self._buffer is not None:
            self._buffer = jax.tree.map(_like, self._buffer,
                                        tree["buffer"])
            self.n_delivered = float(tree["n_delivered"])
        host = tree["host"]
        self._set_rng_state(rng, host["rng"])
        self._set_rng_state(self._fault_rng, host["fault_rng"])
        self._set_rng_state(self._codec_rng, host["codec_rng"])
        if self._latency is not None:
            self._arrival[...] = np.asarray(host["arrival"], np.int64)
            self._dispatch_round[...] = np.asarray(
                host["dispatch_round"], np.int64)
            self._plan_round = int(host["plan_round"])
            self._pending_delays = None
        self.ledger.load_state(tree["ledger"])
        self.history = [{k: float(v) for k, v in h.items()}
                        for h in tree.get("history", [])]
        self._host_snapshot = host
        return int(meta["round"])

    def run(self, rounds: int, eval_fn: Optional[Callable] = None,
            eval_every: int = 10, verbose: bool = False,
            prefetch: bool = True, resume: bool = False):
        cfg = self.cfg
        rng = np.random.RandomState(cfg.seed + 1)
        start = 0
        if resume:
            if not cfg.ckpt_path:
                raise ValueError(
                    "run(resume=True) needs FLConfig.ckpt_path")
            start = self.restore_checkpoint(cfg.ckpt_path, rng)
        # host batch prep for round t+1 overlaps device execution of
        # round t; numerically invisible (same rng stream, same data)
        src = self.prefetcher(rng) if prefetch else rng
        try:
            for r in range(start, rounds):
                m = self.run_round(src)
                if eval_fn is not None and (r + 1) % eval_every == 0:
                    m.update(eval_fn(self.params))
                if verbose and (r + 1) % eval_every == 0:
                    print(f"round {r+1:4d} " +
                          " ".join(f"{k}={v:.4g}" for k, v in m.items()))
                if cfg.ckpt_every and (r + 1) % cfg.ckpt_every == 0:
                    self.save_checkpoint(cfg.ckpt_path)
        finally:
            if prefetch:
                src.close()
        return self.history


# ------------------------------------------------------------- prefetcher

class RoundPrefetcher:
    """Host->device double buffering for the round loop (the ROADMAP's
    "async round overlap" item).

    A daemon thread draws each round's ``(batch, mask)`` from the engine's
    rng IN ROUND ORDER (batches first, then the participation mask —
    exactly the synchronous ``run_round`` order), tags the item with the
    post-draw host-state snapshot checkpointing relies on, and stages the
    device transfers, so round t+1's host prep and H2D copies overlap the
    device executing round t. While the prefetcher is alive it is the rng's only
    consumer, so every number in the round history is bit-identical to the
    synchronous path; the only observable difference is that ``close()``
    leaves the rng advanced by the rounds still sitting in the buffer.
    """

    _SENTINEL = object()

    def __init__(self, engine: "FLEngine", rng: np.random.RandomState,
                 depth: int = 2):
        self._engine = engine
        self._rng = rng
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._produce, name="fl-round-prefetch", daemon=True)
        self._thread.start()

    def _produce(self):
        try:
            while not self._stop.is_set():
                batch = self._engine._sample_batches(self._rng)
                # re-check between the two rng draws: a close() racing this
                # loop must not trigger another _sample_mask -> H2D staging
                # round against an engine that is already tearing down
                if self._stop.is_set():
                    break
                mask = self._engine._sample_mask(self._rng)
                # the post-draw host-state snapshot travels with the item
                # (see FLEngine._capture_host_state): run_round keeps the
                # one matching the round it executes, so a checkpoint cut
                # under prefetch is exact
                item = (batch, mask,
                        self._engine._capture_host_state(self._rng))
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # re-raised on the consumer side
            self._err = e
            while not self._stop.is_set():
                try:
                    self._q.put(self._SENTINEL, timeout=0.05)
                    break
                except queue.Full:
                    continue

    def next(self):
        """The next round's (batch, mask, snapshot); raises if the
        thread died.

        Once the producer has failed, every subsequent call re-raises
        immediately (the sentinel is posted once; without the dead flag a
        retry would block forever on the empty queue), and calling after
        ``close()`` errors instead of deadlocking on the dead producer.

        The wait itself is a timeout-loop ``get`` that re-checks
        ``_stop``/``_err`` every lap: the one-shot pre-checks above are not
        atomic with a blocking ``get()``, so a ``close()`` (or producer
        death) that lands after the checks but before the dequeue used to
        park this thread on an empty queue forever."""
        while True:
            if self._err is not None and self._q.empty():
                raise RuntimeError(
                    "round prefetch thread failed") from self._err
            if self._stop.is_set() and self._q.empty():
                raise RuntimeError("RoundPrefetcher used after close()")
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            if item is self._SENTINEL:
                raise RuntimeError(
                    "round prefetch thread failed") from self._err
            return item

    def close(self):
        self._stop.set()
        while True:  # drain so a blocked put() observes the stop flag
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=10)
        if self._thread.is_alive():
            # a silent failed join leaks a thread that still owns the rng
            # and may touch a torn-down engine; surface it instead
            warnings.warn(
                "RoundPrefetcher thread did not exit within 10s of close(); "
                "it may be wedged in a device transfer",
                RuntimeWarning, stacklevel=2)


# ----------------------------------------------------- host bank streamer

class _HostBankStreamer:
    """Daemon thread streaming host-resident LBG bank chunks (the
    ``"topk-host"`` store) through a double buffer.

    One FIFO task queue serializes three operations:

    * ``("up", c)`` — ``jax.device_put`` chunk ``c``'s bank rows
      (contiguous host-array slices) together with its batch rows, and
      publish the device trees for the round loop's ``get(c)``.
    * ``("wb", c, dev)`` — write chunk ``c``'s updated bank back into
      the host array. The ``np.asarray`` D2H copy blocks until the
      chunk's (asynchronously dispatched) compute finishes — that is
      the only synchronization the pipeline needs.
    * ``("sync", event)`` — end-of-round barrier: when it fires, every
      prior write-back has landed and the host array is the post-round
      bank.

    FIFO ordering also guarantees a chunk's write-back precedes any
    later round's re-upload of the same rows. The round loop keeps two
    uploads in flight (``begin_round`` requests chunks 0 and 1;
    iteration ``c`` requests ``c+2``), so chunk ``c+1``'s H2D transfer
    overlaps chunk ``c``'s compute — the same double-buffer discipline
    :class:`RoundPrefetcher` applies to whole rounds.
    """

    def __init__(self, host_bank, chunk: int):
        self._bank = host_bank   # {name: {idx/val: np (Kp, nb, kb)}}
        self._chunk = chunk
        self._tasks: queue.Queue = queue.Queue()
        self._cv = threading.Condition()
        self._ready: dict = {}
        self._err: Optional[BaseException] = None
        self._batch = None
        self._n_chunks = 0
        self._requested: set = set()
        self._closed = False
        self._thread = threading.Thread(
            target=self._work, name="fl-bank-stream", daemon=True)
        self._thread.start()

    def begin_round(self, batch, n_chunks: int):
        """Arm the streamer with this round's host batch (chunked
        layout) and prefetch the first two chunks."""
        if self._err is not None:
            raise RuntimeError(
                "bank streamer thread failed") from self._err
        self._batch = batch
        self._n_chunks = n_chunks
        self._requested = set()
        with self._cv:
            self._ready.clear()
        self.request(0)
        self.request(1)

    def request(self, c: int):
        if 0 <= c < self._n_chunks and c not in self._requested:
            self._requested.add(c)
            self._tasks.put(("up", c))

    def get(self, c: int):
        """Device ``(bank_chunk, batch_chunk)`` for chunk ``c`` (blocks
        until its upload lands)."""
        with self._cv:
            while c not in self._ready:
                if self._err is not None:
                    raise RuntimeError(
                        "bank streamer thread failed") from self._err
                self._cv.wait(timeout=0.05)
            return self._ready.pop(c)

    def put_writeback(self, c: int, new_bank):
        self._tasks.put(("wb", c, new_bank))

    def finish_round(self):
        evt = threading.Event()
        self._tasks.put(("sync", evt))
        evt.wait()
        self._batch = None
        if self._err is not None:
            raise RuntimeError(
                "bank streamer thread failed") from self._err

    def close(self):
        if self._closed:
            return
        self._closed = True
        self._tasks.put(None)
        self._thread.join(timeout=10)

    def _work(self):
        while True:
            task = self._tasks.get()
            if task is None:
                return
            kind = task[0]
            try:
                if self._err is not None:
                    # after a failure only the barrier still fires (the
                    # round loop re-raises from get/finish_round)
                    if kind == "sync":
                        task[1].set()
                    continue
                if kind == "up":
                    c = task[1]
                    sl = slice(c * self._chunk, (c + 1) * self._chunk)
                    item = jax.device_put((
                        jax.tree.map(lambda a: a[sl], self._bank),
                        {k: v[c] for k, v in self._batch.items()}))
                    with self._cv:
                        self._ready[c] = item
                        self._cv.notify_all()
                elif kind == "wb":
                    c, dev = task[1], task[2]
                    sl = slice(c * self._chunk, (c + 1) * self._chunk)
                    host = jax.tree.map(np.asarray, dev)

                    def copy(dst, src):
                        dst[sl] = src
                    jax.tree.map(copy, self._bank, host)
                elif kind == "sync":
                    task[1].set()
            except BaseException as e:  # surfaced on the consumer side
                self._err = e
                with self._cv:
                    self._cv.notify_all()
                if kind == "sync":
                    task[1].set()
