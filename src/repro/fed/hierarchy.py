"""Hierarchical (edge -> region -> global) aggregation tiers.

Real cross-device deployments do not ship every client payload to one
server: clients upload to a nearby *edge* aggregator, edges forward a
partial aggregate to a *region*, regions to the *global* server
(Konecny et al.'s communication-efficiency strategies motivate exactly
this fan-in). This module puts that topology behind the engine's
aggregator seam without changing any round history:

* :class:`TierMap` resolves ``FLConfig.tiers`` into a client->edge and
  edge->region assignment (contiguous balanced split in client order, or
  a seed-derived shuffle) plus the per-tier wire-byte attribution the
  :class:`~repro.comm.accounting.CommLedger` records each round.
* :class:`HierarchicalAggregator` wraps a *streaming* aggregator
  (:class:`~repro.fed.engine.DenseAggregator` or
  :class:`~repro.fed.engine.SparseTopKAggregator`). Its carry holds the
  inner aggregator's **flat** carry untouched — ``accumulate`` replays
  the inner fold verbatim on it, so ``finalize`` is *bit-for-bit* the
  un-tiered fold — plus an ``(E, ...)`` **edge** carry that scatter-adds
  each client's weighted payload into its edge's partial sum. Summing
  the edge partials (or the region partials built from them) recovers
  the flat carry up to fp32 reassociation — the tree fold a real
  deployment would execute — and the unit tests pin that consistency.

Why keep the flat carry at all?  fp32 addition is not associative: a
genuine tree combine ``(edge_0 + edge_1) + ...`` rounds differently from
the strictly sequential client fold the rest of the engine (and every
golden history) is pinned to. Folding both carries side by side costs
one extra O(E * M_block) buffer and makes "tiered == flat" an identity
instead of a tolerance, which is what lets ``tiers`` compose with every
scheduler/codec/robustness test already in the tree.

Robust rules (median/trimmed-mean collect mode) cannot decompose over
partial aggregates at all — a median of medians is not the median — so
under a robust rule the tier map is accounting-only: the rule sees the
same full payload stack as the flat engine (numerics identical by
construction) and the ledger still attributes per-tier bytes.

Byte attribution per round (``TierMap.round_bytes``): the edge tier
carries exactly the round's real sparse/codec uplink bytes (clients ->
edges is where client payloads travel); every *active* edge (>= 1
participating client) then ships one dense fp32 partial-carry model
upstream, and every active region ships one more — so the upstream
tiers pay ``n_active * 4 * M`` bytes each, the "one partial carry
instead of K payloads" saving that makes hierarchy worthwhile at scale.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

__all__ = ["TierMap", "HierarchicalAggregator", "make_tier_map"]


class TierMap:
    """Client -> edge (-> region) assignment resolved from
    ``FLConfig.tiers`` (see flconfig.py for the accepted spellings)."""

    def __init__(self, num_clients: int, levels, assign: str = "contiguous",
                 seed: int = 0):
        levels = [int(n) for n in levels]
        if not 1 <= len(levels) <= 2:
            raise ValueError(f"tiers levels must be [n_edges] or "
                             f"[n_edges, n_regions], got {levels!r}")
        self.num_clients = int(num_clients)
        self.n_edges = levels[0]
        self.n_regions = levels[1] if len(levels) == 2 else None
        self.assign = assign
        # contiguous balanced split: client k -> edge floor(k*E/K); sizes
        # differ by at most one and stay in client order
        edge_of = (np.arange(self.num_clients, dtype=np.int64)
                   * self.n_edges) // self.num_clients
        if assign == "shuffle":
            # seed-derived permutation on its own dedicated stream (same
            # construction as the attack/straggler cohorts, offset so the
            # three draws are independent)
            perm = np.random.RandomState(
                (seed * 2654435761 + 193) % (2 ** 31)
            ).permutation(self.num_clients)
            edge_of = edge_of[perm]
        elif assign != "contiguous":
            raise ValueError(f"tiers assign must be 'contiguous' or "
                             f"'shuffle', got {assign!r}")
        self.edge_of = edge_of.astype(np.int32)
        if self.n_regions is not None:
            self.region_of = ((np.arange(self.n_edges, dtype=np.int64)
                               * self.n_regions)
                              // self.n_edges).astype(np.int32)
        else:
            self.region_of = None

    # ------------------------------------------------------------ queries
    def edge_ids_padded(self, padded_clients: int) -> np.ndarray:
        """(Kp,) edge id per client slot; phantom pad clients route to
        edge 0 (they only ever contribute exact zeros — the aggregators'
        ``w > 0`` gate)."""
        out = np.zeros(padded_clients, np.int32)
        out[:self.num_clients] = self.edge_of
        return out

    def round_bytes(self, active_clients: np.ndarray, payload_bytes: float,
                    carry_bytes: float) -> Dict[str, float]:
        """Per-tier wire bytes for one round.

        ``active_clients`` — (K,) bool/0-1 participation (sync: sampled
        mask; buffered: the dispatch cohort whose payloads hit the wire).
        ``payload_bytes`` — the round's real client uplink bytes (the
        codec-priced ``wire_bytes`` metric). ``carry_bytes`` — one dense
        fp32 partial-carry model, i.e. ``4 * n_params``.
        """
        act = np.asarray(active_clients)[:self.num_clients] > 0
        edges = np.unique(self.edge_of[act])
        out = {"edge": float(payload_bytes)}
        if self.region_of is not None:
            regions = np.unique(self.region_of[edges]) if edges.size else \
                np.empty(0, np.int32)
            out["region"] = float(edges.size) * float(carry_bytes)
            out["global"] = float(regions.size) * float(carry_bytes)
        else:
            out["global"] = float(edges.size) * float(carry_bytes)
        return out


def make_tier_map(cfg) -> Optional[TierMap]:
    """Resolve ``FLConfig.tiers`` (already shape-validated there) into a
    live :class:`TierMap`, or None for the flat fold."""
    if cfg.tiers is None:
        return None
    if isinstance(cfg.tiers, dict):
        return TierMap(cfg.num_clients, cfg.tiers["levels"],
                       assign=cfg.tiers.get("assign", "contiguous"),
                       seed=cfg.seed)
    return TierMap(cfg.num_clients, cfg.tiers, seed=cfg.seed)


class HierarchicalAggregator:
    """Streaming-aggregator wrapper that folds per-edge partial carries
    alongside the inner aggregator's untouched flat carry.

    The carry is ``{"flat": inner carry, "edge": (E, ...) per-leaf
    partials, "pos": int32 fold cursor}``. Every scheduler that reaches
    this wrapper folds client payloads strictly in client-slot order
    (vmap: one call over all K; chunked/buffered/topk-host: sequential
    chunks from slot 0), so ``pos`` addresses the static ``edge_ids``
    table to route each chunk's clients to their edges.
    """

    def __init__(self, inner, edge_ids: np.ndarray, n_edges: int):
        import jax.numpy as jnp
        self.inner = inner
        self.n_edges = int(n_edges)
        self._edge_ids = jnp.asarray(edge_ids, jnp.int32)
        self.payload_keys = getattr(inner, "payload_keys", None)

    # layout of one edge-partial leaf mirrors the inner carry's leaf
    def init(self, params):
        import jax
        import jax.numpy as jnp
        flat = self.inner.init(params)
        edge = jax.tree.map(
            lambda a: jnp.zeros((self.n_edges,) + a.shape, a.dtype), flat)
        return {"flat": flat, "edge": edge,
                "pos": jnp.zeros((), jnp.int32)}

    def accumulate(self, acc, w, out):
        import jax
        import jax.numpy as jnp
        n = w.shape[0]
        ids = jax.lax.dynamic_slice_in_dim(self._edge_ids, acc["pos"], n)
        # the inner fold runs verbatim on the flat carry -> finalize is
        # bit-for-bit the un-tiered aggregation
        flat = self.inner.accumulate(acc["flat"], w, out)
        if isinstance(out, tuple):
            send, gscale = out

            def body(e_acc, x):
                w_k, send_k, s_k, id_k = x
                coeff = w_k * s_k

                def upd(ai, sk):
                    # same gather-modify-scatter expression as
                    # SparseTopKAggregator.accumulate, applied to the
                    # client's edge row
                    row = ai[id_k]
                    rows = jnp.arange(row.shape[0])[:, None]
                    cur = row[rows, sk["idx"]]
                    new = cur + jnp.where(w_k > 0, coeff * sk["val"], 0.0)
                    return ai.at[id_k].set(
                        row.at[rows, sk["idx"]].set(new))

                return {name: upd(e_acc[name], send_k[name])
                        for name in e_acc}, None

            edge, _ = jax.lax.scan(body, acc["edge"],
                                   (w, send, gscale, ids))
        else:
            def body(e_acc, x):
                w_k, gt_k, id_k = x
                return jax.tree.map(
                    lambda ai, gi: ai.at[id_k].add(jnp.where(
                        w_k > 0, w_k * gi.astype(jnp.float32), 0.0)),
                    e_acc, gt_k), None

            edge, _ = jax.lax.scan(body, acc["edge"], (w, out, ids))
        return {"flat": flat, "edge": edge, "pos": acc["pos"] + n}

    def finalize(self, acc):
        return self.inner.finalize(acc["flat"])

    # --------------------------------------------------- tier inspection
    def edge_partials(self, acc):
        """Per-leaf (E, ...) edge partial carries."""
        return acc["edge"]

    def combine_edges(self, acc):
        """Tree-combined edge partials — equals the flat carry up to fp32
        reassociation (the fold a physical edge->global deployment
        executes)."""
        import jax
        import jax.numpy as jnp
        return jax.tree.map(lambda a: jnp.sum(a, axis=0), acc["edge"])
