"""Byzantine client attacks + fault injection (ROADMAP open item 3).

The client half of the robustness experiment ``repro.fed.robust``
aggregates against: registry-pluggable *attack components* that corrupt a
configured fraction of clients, plus straggler/dropout fault injection on
the engine's existing participation-mask path.

Threat model: a Byzantine client controls its own message to the server
but still speaks the protocol. Payload attacks therefore corrupt the
accumulated stochastic gradient *before* the uplink pipeline and the LBGM
decision — the malicious client's look-back bank, accept/recycle choice
and (idx, val) payload are all computed from the corrupted update, exactly
as a real adversary inside the client would produce them. That is what
makes the LBGM-vs-FedAvg question real: on a recycle round the attacker's
entire influence is one scalar rho against its (also corrupted) bank.

Two component levels:

* ``level = "data"`` — host-side corruption of the Byzantine clients'
  training data, applied once at engine construction (``corrupt(data)``).
  Built-in: ``"label_flip"`` (y -> num_classes - 1 - y).
* ``level = "payload"`` — traced corruption of the per-client accumulated
  gradient inside the jit'd round (``apply(asg, byz, extras)``; ``byz``
  is the client's 0/1 Byzantine flag, threaded through the batch dict so
  it rides the schedulers' existing vmap/chunk/shard_map layouts and the
  RoundPrefetcher unchanged). Built-ins: ``"sign_flip"`` (g -> -g),
  ``"scaled"`` (g -> scale*g model replacement), ``"free_rider"``
  (g -> 0), ``"gaussian"`` (g -> sigma*N(0, I), per-round noise from the
  component's ``round_extras`` seeds).

Determinism: the Byzantine cohort is a fixed ``round(attack_frac * K)``
subset drawn once from a dedicated ``np.random.RandomState`` stream, and
per-round attack randomness (plus ``FLConfig.dropout_frac`` straggler
faults) consumes a separate *fault stream* — the engine's main rng stream
is untouched, so a clean run (``attack=None``, ``dropout_frac=0``) is
bit-for-bit identical to pre-attack round histories and an attacked run
replays exactly under the same seed.

Config surface: ``FLConfig.attack`` / ``attack_frac`` / ``attack_kw`` /
``dropout_frac`` (validated at construction, JSON round-trips through
``ExperimentSpec`` and the CLI). Extend with ``@register_attack``.
"""
from __future__ import annotations

import numpy as np

from repro.fed.registry import ATTACKS, register_attack

#: reserved batch keys the engine strips before the local-SGD scan
BYZ_KEY = "_byz"
SEED_KEY = "_atk_seed"
CSEED_KEY = "_atk_cseed"
STALE_KEY = "_atk_stale"
#: ^ STALE_KEY: under scheduler="buffered" the engine threads each
#: client's rounds-of-delay draw through the batch dict, so an adaptive
#: attack knows how stale its payload will be on delivery and can
#: pre-compensate the server's staleness discount. Absent (treated as
#: fresh) on the synchronous schedulers.


def select_byzantine(num_clients: int, attack_frac: float,
                     seed: int) -> np.ndarray:
    """The fixed Byzantine cohort: a (K,) 0/1 float mask.

    ``round(attack_frac * K)`` distinct clients drawn from a dedicated
    stream (never the engine's batch/mask rng), so the cohort is stable
    across rounds and reproducible under the same seed.
    """
    mask = np.zeros(num_clients, np.float32)
    n_byz = int(round(attack_frac * num_clients))
    if n_byz:
        rng = np.random.RandomState(seed * 2654435761 % (2 ** 31) + 17)
        mask[rng.choice(num_clients, size=n_byz, replace=False)] = 1.0
    return mask


def fault_rng(seed: int) -> np.random.RandomState:
    """The fault stream: per-round attack noise + dropout draws.

    Separate from the engine rng by construction, so enabling attacks or
    dropout never shifts the batch/participation draw stream.
    """
    return np.random.RandomState((seed + 0x5EED) * 48271 % (2 ** 31))


class PayloadAttack:
    """Base: corrupt the accumulated gradient of Byzantine clients.

    ``apply`` runs per client under the schedulers' vmap (``byz`` is a
    scalar 0/1; ``extras`` per-client scalars from :meth:`round_extras`).
    Subclasses implement ``_corrupt(asg, extras) -> asg`` and the base
    gates it on the flag, so honest clients' updates are bit-untouched.
    """

    level = "payload"

    def round_extras(self, rng: np.random.RandomState,
                     num_clients: int) -> dict:
        """Per-round (K,) host arrays to thread through the batch dict."""
        return {}

    def apply(self, asg, byz, extras):
        import jax
        import jax.numpy as jnp
        if byz is None:
            return asg
        bad = self._corrupt(asg, extras)
        return jax.tree.map(lambda h, a: jnp.where(byz > 0, a, h), asg, bad)

    def _corrupt(self, asg, extras):
        raise NotImplementedError


@register_attack("sign_flip")
class SignFlip(PayloadAttack):
    """g -> -scale*g: the classic direction-reversal poisoning."""

    def __init__(self, scale: float = 1.0):
        self.scale = float(scale)

    def _corrupt(self, asg, extras):
        import jax
        return jax.tree.map(lambda x: -self.scale * x, asg)


@register_attack("scaled")
class Scaled(PayloadAttack):
    """g -> scale*g: model replacement — the attacker boosts its update
    to dominate the average (scale ~ K defeats a plain mean)."""

    def __init__(self, scale: float = 10.0):
        self.scale = float(scale)

    def _corrupt(self, asg, extras):
        import jax
        return jax.tree.map(lambda x: self.scale * x, asg)


@register_attack("free_rider")
class FreeRider(PayloadAttack):
    """g -> 0: contributes nothing while still being averaged in (cf. the
    blades FedModel free-rider client)."""

    def _corrupt(self, asg, extras):
        import jax
        import jax.numpy as jnp
        return jax.tree.map(jnp.zeros_like, asg)


@register_attack("gaussian")
class Gaussian(PayloadAttack):
    """g -> sigma * N(0, I): pure-noise updates, fresh each round.

    Noise keys ride the batch dict as per-client uint32 seeds drawn from
    the fault stream (``round_extras``), so the attack replays exactly
    under a fixed seed and the prefetch thread stays the only consumer of
    host randomness.
    """

    def __init__(self, sigma: float = 1.0):
        self.sigma = float(sigma)

    def round_extras(self, rng, num_clients):
        return {SEED_KEY: rng.randint(
            0, 2 ** 31 - 1, size=num_clients).astype(np.uint32)}

    def _corrupt(self, asg, extras):
        import jax
        import jax.numpy as jnp
        key = jax.random.PRNGKey(extras[SEED_KEY])
        out = {}
        for i, (name, x) in enumerate(asg.items()):
            leaf_key = jax.random.fold_in(key, i)
            out[name] = (self.sigma
                         * jax.random.normal(leaf_key, x.shape, jnp.float32)
                         ).astype(x.dtype)
        return out


@register_attack("colluding_sign")
class ColludingSign(PayloadAttack):
    """The whole Byzantine cohort pushes one shared malicious direction.

    Independent sign flips partially cancel under a mean and are easy
    for a geometric median to out-vote; a *colluding* cohort instead
    agrees (via one shared per-round seed from the fault stream —
    ``round_extras`` broadcasts the same uint32 to every client) on a
    single random unit direction and each member submits
    ``-scale * ||g_k|| * u``, i.e. its own update's mass aimed down the
    agreed direction. This is the coordinated variant the robust-
    aggregation literature treats as the harder case (cf. blades'
    ALIE-style collusion).
    """

    def __init__(self, scale: float = 1.0):
        self.scale = float(scale)

    def round_extras(self, rng, num_clients):
        shared = rng.randint(0, 2 ** 31 - 1)
        return {CSEED_KEY: np.full(num_clients, shared, np.uint32)}

    def _corrupt(self, asg, extras):
        import jax
        import jax.numpy as jnp
        key = jax.random.PRNGKey(extras[CSEED_KEY])
        n2 = 0.0
        d2 = 0.0
        dirs = {}
        for i, (name, x) in enumerate(asg.items()):
            n2 = n2 + jnp.sum(jnp.square(x.astype(jnp.float32)))
            dirs[name] = jax.random.normal(jax.random.fold_in(key, i),
                                           x.shape, jnp.float32)
            d2 = d2 + jnp.sum(jnp.square(dirs[name]))
        coeff = (-self.scale * jnp.sqrt(n2)
                 / jnp.maximum(jnp.sqrt(d2), 1e-12))
        return {name: (coeff * dirs[name]).astype(asg[name].dtype)
                for name in asg}


@register_attack("adaptive_scaled")
class AdaptiveScaled(PayloadAttack):
    """g -> -scale * (1 + s)^alpha * g: amplification matched to the
    cohort's payload norm and to staleness.

    Flipping the client's own accumulated gradient keeps the attack
    magnitude proportional to the cohort's current payload norm (unlike
    a fixed-sigma noise attack, it never over- or under-shoots as
    training converges). Under the buffered scheduler the engine threads
    each client's delay draw in as ``STALE_KEY``, and the attacker
    amplifies by ``(1 + s)^alpha`` to cancel the server's
    ``1/(1+s)^alpha`` staleness discount — a stale Byzantine payload
    lands with the same effective mass as a fresh one. On synchronous
    schedulers ``STALE_KEY`` is absent and this degrades to an
    amplified sign flip.
    """

    def __init__(self, scale: float = 4.0, alpha: float = 0.5):
        self.scale = float(scale)
        self.alpha = float(alpha)

    def _corrupt(self, asg, extras):
        import jax
        import jax.numpy as jnp
        amp = jnp.float32(self.scale)
        s = extras.get(STALE_KEY)
        if s is not None:
            amp = amp * (1.0 + s.astype(jnp.float32)) ** self.alpha
        return jax.tree.map(
            lambda x: (-amp * x.astype(jnp.float32)).astype(x.dtype), asg)


@register_attack("label_flip")
class LabelFlip:
    """Data-level poisoning: y -> num_classes - 1 - y on the Byzantine
    clients' local shards, applied once at engine construction."""

    level = "data"

    def __init__(self, num_classes: int = 10):
        self.num_classes = int(num_classes)

    def corrupt(self, data: dict) -> dict:
        if "y" not in data:
            raise ValueError(
                "label_flip attack needs integer labels under data key "
                f"'y'; client data has keys {sorted(data)} — use a "
                "payload-level attack (sign_flip/scaled/gaussian/"
                "free_rider) for unlabeled tasks")
        return {**data, "y": (self.num_classes - 1 - data["y"]).astype(
            data["y"].dtype)}


def make_attack(cfg):
    """Resolve ``cfg.attack`` through the registry (None -> no attack),
    with an actionable error when ``attack_kw`` doesn't match."""
    if cfg.attack is None:
        return None
    try:
        return ATTACKS.get(cfg.attack)(**(cfg.attack_kw or {}))
    except TypeError as e:
        raise ValueError(
            f"FLConfig.attack_kw {cfg.attack_kw!r} does not match attack "
            f"{cfg.attack!r}: {e}") from e
