"""Declarative experiment API: ``ExperimentSpec`` -> ``run_experiment``.

The paper positions LBGM as plug-and-play across models, datasets and
sparsifiers (P3/P4); this module makes an FL experiment a first-class,
serializable object instead of hand-wired glue. A frozen
:class:`ExperimentSpec` names every component by registry key (model, data,
partition), embeds the canonical :class:`~repro.fed.flconfig.FLConfig`
knobs, and round-trips losslessly through plain dicts / JSON — so a spec
file *is* the experiment, and a sweep is just a list of specs.

Entry points:

* ``build_experiment(spec) -> (FLEngine, eval_fn)`` — resolve components
  and wire the engine (the only place outside tests that should construct
  ``FLEngine`` directly).
* ``run_experiment(spec, rounds=None) -> ExperimentResult`` — build, run,
  evaluate per the spec's :class:`EvalPolicy`, and return typed round
  records plus uplink accounting. The engine's ``history`` is reproduced
  bit-for-bit by an equivalent hand-wired ``FLEngine`` run on the same
  seed (tested in ``tests/test_experiment.py``).
* ``sweep(base_spec, overrides) -> [(point, ExperimentResult)]`` — grid or
  explicit list of dotted-key overrides
  (e.g. ``{"fl.delta_threshold": [.01, .2]}``), the driver behind the
  Fig. 6 threshold sweep.
* ``python -m repro.fed.run --spec spec.json --set key=value`` — CLI over
  the same objects (see ``repro.fed.run``).

Extension points: ``@register_model`` / ``@register_dataset`` /
``@register_partitioner`` (this module registers the paper-native
built-ins), plus ``@register_compressor`` / ``@register_scheduler`` /
``@register_lbg_store`` consumed by the engine layer.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Mapping, Optional, \
    Tuple, Union

import numpy as np

from repro.fed.flconfig import FLConfig
from repro.fed.registry import (DATASETS, MODELS, PARTITIONERS,
                                register_dataset, register_model,
                                register_partitioner)

# --------------------------------------------------------------- spec types


@dataclass(frozen=True)
class ComponentSpec:
    """A registry key plus its keyword arguments: ``("mixture", {"n": 2000})``."""
    name: str
    kw: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class EvalPolicy:
    """When to run held-out evaluation during/after an experiment."""
    every: int = 0          # eval every N rounds (0 = never during the run)
    final: bool = True      # eval once after the last round
    verbose: bool = False   # print per-eval progress lines

    def __post_init__(self):
        if self.every < 0:
            raise ValueError(
                f"EvalPolicy: every must be >= 0, got {self.every}")


@dataclass(frozen=True)
class ExperimentSpec:
    """The complete, serializable description of one FL experiment."""
    model: ComponentSpec = field(
        default_factory=lambda: ComponentSpec("fcn"))
    data: ComponentSpec = field(
        default_factory=lambda: ComponentSpec("mixture"))
    partition: ComponentSpec = field(
        default_factory=lambda: ComponentSpec("label_skew"))
    fl: FLConfig = field(default_factory=FLConfig)
    rounds: int = 40
    eval: EvalPolicy = field(default_factory=EvalPolicy)
    name: str = "experiment"

    # ---------------------------------------------------------- validation
    def validate(self) -> "ExperimentSpec":
        """Check registry keys and ranges; error messages name the fix.

        ``fl`` already validated itself at construction; this covers the
        spec-level fields.
        """
        if self.rounds < 1:
            raise ValueError(
                f"ExperimentSpec: rounds must be >= 1, got {self.rounds}")
        for reg, comp in ((MODELS, self.model), (DATASETS, self.data),
                          (PARTITIONERS, self.partition)):
            if comp.name not in reg:
                raise ValueError(
                    f"ExperimentSpec: unknown {reg.kind} {comp.name!r}; "
                    f"registered {reg.kind}s: {reg.names()}")
        return self

    # ------------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"ExperimentSpec: unknown fields {sorted(unknown)}; "
                f"known fields: {sorted(known)}")
        for key in ("model", "data", "partition"):
            if key in d and isinstance(d[key], Mapping):
                d[key] = ComponentSpec(**d[key])
        if isinstance(d.get("fl"), Mapping):
            d["fl"] = FLConfig.from_dict(d["fl"])
        if isinstance(d.get("eval"), Mapping):
            d["eval"] = EvalPolicy(**d["eval"])
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    # ----------------------------------------------------------- overrides
    def with_overrides(self, overrides: Mapping[str, Any]) -> "ExperimentSpec":
        """New spec with dotted-key overrides applied, e.g.
        ``{"fl.delta_threshold": 0.4, "model.kw.arch": "paper-cnn"}``.

        Works through the dict round-trip so any serializable field is
        addressable; re-validation happens on reconstruction.
        """
        def is_open(key):  # kw dicts take arbitrary component kwargs
            return key == "kw" or key.endswith("_kw")

        d = self.to_dict()
        for dotted, value in overrides.items():
            parts = dotted.split(".")
            node = d
            for p in parts[:-1]:
                if not isinstance(node, dict) or p not in node:
                    raise ValueError(
                        f"ExperimentSpec: unknown override key {dotted!r} "
                        f"(no field {p!r}; known: "
                        f"{sorted(node) if isinstance(node, dict) else []})")
                if node[p] is None and is_open(p):
                    node[p] = {}
                node = node[p]
            leaf = parts[-1]
            if not isinstance(node, dict):
                raise ValueError(
                    f"ExperimentSpec: unknown override key {dotted!r}")
            if leaf not in node and not (len(parts) > 1
                                         and is_open(parts[-2])):
                raise ValueError(
                    f"ExperimentSpec: unknown override key {dotted!r}; "
                    f"known keys here: {sorted(node)}")
            node[leaf] = value
        return type(self).from_dict(d)


# ------------------------------------------------------------ result types


#: history keys copied verbatim from ``FLEngine.run_round`` metrics into
#: each :class:`RoundRecord` — must track the engine's history keys in
#: lockstep (``result.history`` is asserted float-exact against
#: ``FLEngine.history`` in tests/test_experiment.py)
_HISTORY_KEYS = ("loss", "uplink_floats", "frac_scalar", "wire_bytes",
                 "total_uplink", "vanilla_uplink", "savings",
                 "total_wire_bytes", "wire_savings")


@dataclass
class RoundRecord:
    """One FL round's server-side metrics (mirrors ``FLEngine.history``)."""
    round: int
    loss: float
    uplink_floats: float
    frac_scalar: float
    total_uplink: float
    vanilla_uplink: float
    savings: float
    # real-byte wire accounting (repro.comm.wire / FLConfig.codec)
    wire_bytes: float = 0.0
    total_wire_bytes: float = 0.0
    wire_savings: float = 0.0
    eval: Dict[str, float] = field(default_factory=dict)

    def as_history_entry(self) -> Dict[str, float]:
        return {"loss": self.loss, "uplink_floats": self.uplink_floats,
                "frac_scalar": self.frac_scalar,
                "wire_bytes": self.wire_bytes,
                "total_uplink": self.total_uplink,
                "vanilla_uplink": self.vanilla_uplink,
                "savings": self.savings,
                "total_wire_bytes": self.total_wire_bytes,
                "wire_savings": self.wire_savings}


@dataclass
class ExperimentResult:
    """Typed outcome of ``run_experiment``: round records + accounting."""
    spec: ExperimentSpec
    rounds: int
    records: List[RoundRecord]
    final_eval: Dict[str, float]
    total_uplink: float
    vanilla_uplink: float
    savings: float
    duration_s: float

    @property
    def history(self) -> List[Dict[str, float]]:
        """Engine-compatible history (bit-equal to ``FLEngine.history``)."""
        return [r.as_history_entry() for r in self.records]

    @property
    def us_per_round(self) -> float:
        return self.duration_s / max(self.rounds, 1) * 1e6

    def to_dict(self) -> Dict[str, Any]:
        return {
            "spec": self.spec.to_dict(),
            "rounds": self.rounds,
            "records": [dataclasses.asdict(r) for r in self.records],
            "final_eval": self.final_eval,
            "total_uplink": self.total_uplink,
            "vanilla_uplink": self.vanilla_uplink,
            "savings": self.savings,
            "duration_s": self.duration_s,
        }


# ------------------------------------------------------------ entry points


def build_experiment(spec: ExperimentSpec):
    """Resolve the spec's components and wire the engine.

    Returns ``(engine, eval_fn)`` where ``eval_fn(params)`` evaluates on
    the dataset's held-out split (``{"test_loss": ..., "test_acc": ...}``).
    """
    from repro.fed.engine import FLEngine
    import jax.numpy as jnp

    spec.validate()
    # model init seed defaults to the experiment seed; an explicit
    # model.kw["seed"] wins (kw dicts are open-ended override surface)
    built = MODELS.get(spec.model.name)(
        **{"seed": spec.fl.seed, **spec.model.kw})
    # components may return (params, loss_fn) or, for architectures that
    # know their tensor-parallel layout, (params, loss_fn, axes_tree) —
    # the per-leaf named-axis metadata fl.model_sharding="auto" needs
    params, loss_fn, model_axes = (
        built if len(built) == 3 else (*built, None))
    train, held_out = DATASETS.get(spec.data.name)(**spec.data.kw)
    n_held = len(next(iter(held_out.values()))) if held_out else 0
    if n_held == 0 and (spec.eval.final or spec.eval.every):
        raise ValueError(
            "ExperimentSpec: the eval policy requests evaluation but the "
            "dataset's held-out split is empty (a mean over zero samples "
            "is NaN); grow it (e.g. data.kw n_eval > 0) or disable eval "
            "with EvalPolicy(every=0, final=False)")
    parts = PARTITIONERS.get(spec.partition.name)(
        train, spec.fl.num_clients, **spec.partition.kw)
    client_data = [{k: v[p] for k, v in train.items()} for p in parts]
    engine = FLEngine(loss_fn, params, client_data, spec.fl,
                      model_axes=model_axes)

    eval_batch = {k: jnp.asarray(v) for k, v in held_out.items()}

    def eval_fn(params) -> Dict[str, float]:
        loss, metrics = loss_fn(params, eval_batch)
        out = {"test_loss": float(loss)}
        if "acc" in metrics:
            out["test_acc"] = float(metrics["acc"])
        return out

    return engine, eval_fn


def run_experiment(spec: ExperimentSpec,
                   rounds: Optional[int] = None,
                   resume: bool = False) -> ExperimentResult:
    """Build the spec's experiment, run it, and return the typed result.

    The round loop is identical to ``FLEngine.run`` (same RNG stream, same
    per-round calls), so ``result.history`` matches a hand-wired engine's
    ``history`` bit-for-bit on the same seed; evaluation per
    ``spec.eval`` is layered on top without touching the engine history.
    Host batch prep rides the engine's :class:`~repro.fed.engine.
    RoundPrefetcher` (round t+1 prepared while t executes) — numerically
    invisible, same rng stream. The spec's ``fl.fused_kernels`` knob (and
    every other FLConfig field) JSON round-trips through the spec, so a
    saved spec pins the execution path too.

    ``resume=True`` restores the checkpoint at ``spec.fl.ckpt_path``
    (written per ``spec.fl.ckpt_every``) before the loop and continues
    from the saved round; the completed history is bit-for-bit the
    uninterrupted run's (rng streams, banks, buffered in-flight slots and
    ledger all travel in the checkpoint). Records replayed from the
    checkpointed engine history carry no per-round eval (eval is a pure
    read of params, re-runnable offline); ``final_eval`` is unaffected.
    """
    rounds = spec.rounds if rounds is None else rounds
    engine, eval_fn = build_experiment(spec)
    policy = spec.eval
    records: List[RoundRecord] = []
    rng = np.random.RandomState(spec.fl.seed + 1)
    start = 0
    if resume:
        if not spec.fl.ckpt_path:
            raise ValueError("run_experiment(resume=True) needs "
                             "fl.ckpt_path set in the spec")
        start = engine.restore_checkpoint(spec.fl.ckpt_path, rng)
        records = [RoundRecord(round=i + 1, eval={},
                               **{k: h[k] for k in _HISTORY_KEYS})
                   for i, h in enumerate(engine.history)]
    # accumulate round time only — held-out eval must not contaminate the
    # us_per_round metric the benchmarks report. Host batch prep is
    # double-buffered on the engine's prefetch thread (same rng stream,
    # bit-identical history), so us_per_round measures the device round
    # with round t+1's prep overlapped — the steady-state serving shape.
    duration = 0.0
    src = engine.prefetcher(rng)
    try:
        for r in range(start, rounds):
            t0 = time.time()
            m = engine.run_round(src)
            duration += time.time() - t0
            ev: Dict[str, float] = {}
            if policy.every and (r + 1) % policy.every == 0:
                ev = eval_fn(engine.params)
                if policy.verbose:
                    shown = {**m, **ev}
                    print(f"[{spec.name}] round {r+1:4d} " +
                          " ".join(f"{k}={v:.4g}"
                                   for k, v in shown.items()))
            records.append(RoundRecord(round=r + 1, eval=ev,
                                       **{k: m[k] for k in _HISTORY_KEYS}))
            if spec.fl.ckpt_every and (r + 1) % spec.fl.ckpt_every == 0:
                engine.save_checkpoint(spec.fl.ckpt_path)
    finally:
        src.close()
    final_eval = eval_fn(engine.params) if policy.final else {}
    return ExperimentResult(
        spec=spec, rounds=rounds, records=records, final_eval=final_eval,
        total_uplink=engine.total_uplink,
        vanilla_uplink=engine.vanilla_uplink,
        savings=records[-1].savings if records else 0.0,
        duration_s=duration)


OverridesLike = Union[Mapping[str, Iterable[Any]],
                      Iterable[Mapping[str, Any]]]


def expand_overrides(overrides: OverridesLike) -> List[Dict[str, Any]]:
    """Normalize sweep input to a list of dotted-key override dicts.

    A mapping of ``key -> list of values`` expands to the cartesian grid;
    an iterable of dicts passes through as explicit sweep points.
    """
    if isinstance(overrides, Mapping):
        keys = list(overrides)
        grids = [list(overrides[k]) for k in keys]
        return [dict(zip(keys, combo)) for combo in itertools.product(*grids)]
    return [dict(o) for o in overrides]


def sweep(base_spec: ExperimentSpec, overrides: OverridesLike,
          rounds: Optional[int] = None,
          ) -> List[Tuple[Dict[str, Any], ExperimentResult]]:
    """Run ``base_spec`` under each override set; returns
    ``[(overrides_dict, result), ...]`` in grid order. Each result's
    ``spec`` carries the fully resolved configuration."""
    out = []
    for point in expand_overrides(overrides):
        spec = base_spec.with_overrides(point)
        out.append((point, run_experiment(spec, rounds)))
    return out


# --------------------------------------------------------------- built-ins
#
# Paper-native components. Model builders return ``(params, loss_fn)`` or
# ``(params, loss_fn, axes_tree)`` where ``axes_tree`` names each leaf's
# dimensions for tensor-parallel layout (consumed when
# ``fl.model_sharding="auto"``); dataset builders return
# ``(train, held_out)`` dicts of numpy arrays; partitioners map
# ``(train, num_clients, **kw)`` to per-client index lists.


def _classifier_model(arch: str, seed: int, init_fn, apply_fn,
                      **arch_overrides):
    import jax
    from repro.configs import get_config
    from repro.models.smallnets import classifier_loss

    cfg = get_config(arch)
    if arch_overrides:
        cfg = dataclasses.replace(cfg, **arch_overrides)
    params, _ = init_fn(jax.random.PRNGKey(seed), cfg)
    loss_fn = lambda p, b: classifier_loss(apply_fn, p, cfg, b["x"], b["y"])
    return params, loss_fn


@register_model("fcn")
def _fcn_model(seed: int = 0, arch: str = "paper-fcn", **arch_overrides):
    """Paper S2: 1-hidden-layer FCN classifier on 28x28 inputs."""
    from repro.models.smallnets import apply_fcn, init_fcn
    return _classifier_model(arch, seed, init_fcn, apply_fcn,
                             **arch_overrides)


@register_model("cnn")
def _cnn_model(seed: int = 0, arch: str = "paper-cnn", **arch_overrides):
    """Paper S1: small conv classifier on 28x28 inputs."""
    from repro.models.smallnets import apply_cnn, init_cnn
    return _classifier_model(arch, seed, init_cnn, apply_cnn,
                             **arch_overrides)


@register_model("lm")
def _lm_model(seed: int = 0, arch: str = "qwen3-1.7b", reduced: bool = True,
              **arch_overrides):
    """Next-token LM on one of the assigned large archs (``repro.configs``
    names: yi-34b, deepseek-67b, ...), default ``reduced()`` so the spec
    runs on a CPU container; drop ``reduced`` on real accelerators. This
    is the large-arch entry into the declarative API — the 2-D
    ``(clients, model)`` mesh example in ``examples/`` runs a reduced
    yi-34b through it."""
    import jax
    from repro.configs import get_config
    from repro.models.transformer import init_lm, lm_loss

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if arch_overrides:
        cfg = dataclasses.replace(cfg, **arch_overrides)
    params, axes = init_lm(jax.random.PRNGKey(seed), cfg)
    loss_fn = lambda p, b: lm_loss(p, cfg, b["tokens"], b["labels"])
    # third element: the arch's named-axis tree, so fl.model_sharding=
    # "auto" can lay the transformer out over the mesh's model axis
    return params, loss_fn, axes


@register_dataset("mixture")
def _mixture_dataset(n: int = 2000, n_eval: int = 500, num_classes: int = 10,
                     seed: int = 0, noise: float = 0.35):
    """Gaussian-prototype 28x28 classification (MNIST/FMNIST stand-in)."""
    from repro.data.synthetic import mixture_classification
    x, y = mixture_classification(n + n_eval, num_classes, seed=seed,
                                  noise=noise)
    return ({"x": x[:n], "y": y[:n]}, {"x": x[n:], "y": y[n:]})


@register_dataset("markov")
def _markov_dataset(n: int = 256, n_eval: int = 64, seq_len: int = 32,
                    vocab: int = 512, seed: int = 0, branching: int = 4):
    """Markov-chain LM stream (the large-arch training driver's data):
    each token has ``branching`` likely successors — learnable structure
    for the ``"lm"`` model component. ``vocab`` must match the arch's
    (reduced archs clamp to 512)."""
    from repro.data.synthetic import markov_lm
    toks, labels = markov_lm(n + n_eval, seq_len, vocab, seed=seed,
                             branching=branching)
    return ({"tokens": toks[:n], "labels": labels[:n]},
            {"tokens": toks[n:], "labels": labels[n:]})


@register_partitioner("label_skew")
def _label_skew_partitioner(train, num_clients: int,
                            classes_per_client: int = 3, seed: int = 0):
    """Non-iid S1 split: each client sees only a few labels."""
    from repro.fed.partition import partition_label_skew
    return partition_label_skew(train["y"], num_clients,
                                classes_per_client, seed=seed)


@register_partitioner("iid")
def _iid_partitioner(train, num_clients: int, seed: int = 0):
    from repro.fed.partition import partition_iid
    n = len(next(iter(train.values())))
    return partition_iid(n, num_clients, seed=seed)
