"""CLI entry point for the declarative experiment API.

    PYTHONPATH=src python -m repro.fed.run --spec spec.json \
        --set fl.delta_threshold=0.4 --set model.name=cnn --rounds 20

Without ``--spec`` a small built-in spec runs (4-client FCN on the mixture
dataset) — handy as a smoke test and as a template: ``--print-spec`` dumps
the fully resolved spec as JSON without running, so

    python -m repro.fed.run --print-spec > spec.json

bootstraps a spec file you can edit and feed back in. ``--set`` takes
dotted keys into the spec (``fl.*``, ``model.kw.*``, ...); values parse as
JSON when possible, else as strings.

Multi-device execution rides the same knobs: ``--set
fl.scheduler=sharded --set fl.mesh=4`` runs each chunk's clients
data-parallel on a 4-device client mesh, and ``--set "fl.mesh=[2,4]"``
asks for the 2-D (clients, model) mesh — 2-way client parallelism with
the LBGM banks/decision sharded 4 ways along the model axis (an int
mesh ``n`` is exactly ``[n, 1]``; force host devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU). See
``examples/specs/yi34b_mesh2x4.json`` for a full 2-D large-arch spec.

``--set fl.model_sharding=auto`` additionally runs each client's
local-SGD forward/backward tensor-parallel along the model axis
(default ``replicate`` keeps it replicated — bit-for-bit the pre-knob
engine). Requires ``fl.scheduler=sharded``, a model component that
carries sharding metadata (``model.name=lm``; fcn/cnn refuse),
``fl.lbg_variant=topk-sharded`` and ``fl.compressor=none``; histories
match ``replicate`` at fp32 tolerance with identical uplink
accounting. ``examples/specs/yi34b_tp2x4.json`` runs the full yi-34b
layer count (width-reduced) tensor-parallel on a 2x4 mesh.

The uplink wire codec rides the same knobs: ``--set fl.codec=int8``
(or ``fp8`` / ``delta_idx``) quantizes the sparse LBGM payloads to ~1
byte/value with per-block-row power-of-two scales and varint-delta
indices — needs the sparse payload path (``fl.lbg_variant=topk`` or
``topk-sharded``) or vanilla FL (``fl.use_lbgm=false``); ``--set
"fl.codec_kw={\"stochastic\": false}"`` switches to nearest rounding.
``codec=none`` (default) ships fp32 bit-for-bit. Real bytes land in the
history as ``wire_bytes`` / ``wire_savings`` (see ``repro.comm.wire``
for the wire format); ``examples/specs/quantized_lbgm.json`` is a full
int8 LBGM spec.

Buffered async aggregation (FedBuff-style) rides the same knobs:
``--set fl.scheduler=buffered --set fl.latency=straggler --set
"fl.latency_kw={\"frac\": 0.2, \"delay\": 4}"`` treats slow clients as
*latency* instead of dropout — a dispatched payload sits in flight for a
model-drawn number of rounds and folds into the global update in its
arrival round, discounted by ``1/(1+staleness)**alpha``. Latency models:
``none`` (default; with it, buffered is bit-for-bit the chunked
scheduler), ``fixed``, ``uniform``, ``lognormal``, ``straggler`` (fixed
seed-derived slow cohort; ``drop=true`` makes the cohort never deliver —
the dropout baseline — and ``slow_tau`` gives it a smaller local-step
budget). Needs the sparse payload path (``fl.lbg_variant=topk`` /
``topk-sharded``); wire/uplink bytes are attributed to the arrival
round. ``examples/specs/async_buffered.json`` is a full spec;
``benchmarks/async_heterogeneity.py`` is the dropout-vs-buffered grid.
``"fl.latency_kw={\"max_staleness\": 8}"`` (any model) evicts in-flight
payloads older than 8 rounds instead of parking them forever; evictions
land in the ledger as ``n_evicted``.

Out-of-core client banks: ``--set fl.lbg_variant=topk-host`` keeps the
per-client LBG banks host-resident (NumPy) and streams one chunk's bank
to the device per scan step on a background thread — device bank memory
is O(chunk_size), independent of ``num_clients``, bit-for-bit equal to
``topk``. Needs ``fl.scheduler=chunked``, a streaming aggregator
(``mean``), and no error feedback. ``examples/specs/hier_100k.json``
runs a 100k-client round this way.

Hierarchical aggregation: ``--set "fl.tiers=[32,4]"`` routes clients
through 32 edge aggregators and 4 regions before the global server
(contiguous balanced assignment; ``--set "fl.tiers={\"levels\": [32,4],
\"assign\": \"shuffle\"}"`` for a seed-derived shuffle). Histories stay
bit-for-bit the flat fold (see ``repro.fed.hierarchy``); the ledger
gains per-tier wire bytes (``tier_wire_bytes``).

Checkpoint/resume: ``--set fl.ckpt_every=10 --set
fl.ckpt_path=run.ckpt.npz`` atomically checkpoints params, LBG banks,
rng streams, buffered in-flight slots and the comm ledger every 10
rounds; re-running with ``--resume`` picks up from the latest
checkpoint and finishes with a history bit-for-bit equal to the
uninterrupted run.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

from repro.fed.experiment import (ComponentSpec, EvalPolicy, ExperimentSpec,
                                  run_experiment)
from repro.fed.flconfig import FLConfig


def default_spec() -> ExperimentSpec:
    """Tiny 4-client FCN experiment: fast enough for CI smoke runs."""
    return ExperimentSpec(
        name="quick-fcn",
        model=ComponentSpec("fcn"),
        data=ComponentSpec("mixture", {"n": 400, "n_eval": 200}),
        partition=ComponentSpec("label_skew", {"classes_per_client": 3}),
        fl=FLConfig(num_clients=4, tau=2, lr=0.05, batch_size=16,
                    use_lbgm=True, delta_threshold=0.2),
        rounds=10,
        eval=EvalPolicy(every=5, final=True, verbose=True),
    )


def parse_set(kvs) -> dict:
    """``["a.b=1", "c=x"]`` -> ``{"a.b": 1, "c": "x"}`` (JSON-ish values)."""
    out = {}
    for kv in kvs or ():
        if "=" not in kv:
            raise SystemExit(f"--set expects key=value, got {kv!r}")
        key, _, raw = kv.partition("=")
        try:
            out[key] = json.loads(raw)
        except json.JSONDecodeError:
            out[key] = raw
    return out


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fed.run",
        description="Run one declarative FL experiment from a spec.")
    ap.add_argument("--spec", default=None,
                    help="path to an ExperimentSpec JSON file "
                         "(default: built-in quick-fcn spec)")
    ap.add_argument("--set", dest="sets", action="append", metavar="KEY=VAL",
                    help="dotted-key spec override, repeatable "
                         "(e.g. --set fl.delta_threshold=0.4)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the spec's round count")
    ap.add_argument("--out", default=None,
                    help="write the full result (records + spec) as JSON")
    ap.add_argument("--print-spec", action="store_true",
                    help="print the resolved spec as JSON and exit")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the checkpoint at fl.ckpt_path "
                         "(requires fl.ckpt_every/fl.ckpt_path in the "
                         "spec); the completed history is bit-for-bit "
                         "the uninterrupted run's")
    args = ap.parse_args(argv)

    spec = (ExperimentSpec.load(args.spec) if args.spec else default_spec())
    overrides = parse_set(args.sets)
    if overrides:
        spec = spec.with_overrides(overrides)
    if args.rounds is not None:
        spec = spec.with_overrides({"rounds": args.rounds})
    if args.print_spec:
        print(spec.to_json())
        return 0

    result = run_experiment(spec, resume=args.resume)
    last = result.records[-1]
    print(f"[{spec.name}] {result.rounds} rounds in "
          f"{result.duration_s:.2f}s "
          f"({result.us_per_round / 1e3:.1f} ms/round)")
    print(f"  loss={last.loss:.4f} frac_scalar={last.frac_scalar:.2f} "
          f"uplink={result.total_uplink:.3g} floats "
          f"savings={result.savings:.1%}")
    print(f"  wire={last.total_wire_bytes:.3g} bytes "
          f"(codec={spec.fl.codec}) wire_savings={last.wire_savings:.1%}")
    if result.final_eval:
        print("  " + " ".join(f"{k}={v:.4f}"
                              for k, v in sorted(result.final_eval.items())))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result.to_dict(), f, indent=2)
        print(f"  result written to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
