"""Byzantine-robust server aggregation (ROADMAP open item 3).

LBGM collapses a client's update to one scalar per bank entry on a recycle
round, which raises a question the paper never answers: is scalar-round
aggregation more or less robust to poisoned clients than dense FedAvg?
This module supplies the server half of that experiment: registry-pluggable
*aggregation rules* that replace the engine's weighted-mean fold with a
robust location estimate of the per-client update distribution.

Two aggregation modes share the engine's aggregator seam
(``sched.run(client_fn, agg, ...)``):

* **streaming** (``"mean"``, the default) — the existing strictly
  sequential ``carry += w_k * g_k`` fold (``DenseAggregator`` /
  ``SparseTopKAggregator`` in ``fed/engine.py``). O(1) client state at a
  time; bit-for-bit identical to every pre-robustness round history.
* **collect** (every robust rule) — a median cannot be folded one client
  at a time, so the schedulers stack the per-client payloads (dense
  g_tilde *or* the sparse (idx, val) scalar-round payload + gscale) across
  chunks and hand the full (K, ...) stack to the rule's :meth:`reduce`.
  Peak memory is O(K·M) — the honest price of a coordinate-wise
  cross-client view; the sparse payload is densified to the bank's block
  layout first (gscale folded in), so robust rules see the same update
  vectors the mean would have accumulated.

Every rule is *weighted*: the engine passes the round's normalized client
weights (data-size x participation, summing to 1 over participants), so
zero-weight clients — unsampled, dropped out, or phantom chunk padding
(whose values may be NaN) — carry no mass and are masked out of every
estimate. All rules are pure ``jnp`` with static shapes (the geometric
median is a fixed-iteration smoothed Weiszfeld), so they jit and shard
like the rest of the round function.

Staleness-aware weighting (the ``"buffered"`` scheduler) arrives through
that same weight vector: the scheduler multiplies each *delivered*
buffer row's dispatch-round weight by the latency model's discount
``1/(1+s)^alpha`` before normalizing, so mean, geometric_median and
scalar_median all downweight stale payloads with zero rule-side code —
a rule that honors ``w`` is automatically staleness-aware, and
undelivered buffer rows are ordinary zero-weight clients.

Built-in rules (``repro.fed.registry.AGGREGATORS``; extend with
``@register_aggregator``):

* ``"mean"``            — streaming marker (see above), the default.
* ``"trimmed_mean"``    — per-coordinate weighted trimmed mean: the
  ``beta`` weight-mass tails of the sorted per-coordinate distribution are
  discarded and the remaining mass averaged (``beta=0.1``).
* ``"coordinate_median"`` (alias ``"median"``) — per-coordinate weighted
  median (the 0.5 weight-mass crossing of the sorted values).
* ``"geometric_median"`` (alias ``"gm"``) — smoothed Weiszfeld iteration
  toward argmin_z sum_k w_k ||g_k - z|| over whole update vectors
  (``iters=8``, ``eps=1e-6``; cf. blades' AutoGM aggregator). Fixed
  iteration count so the round function stays static for pjit/TPU.

Config surface: ``FLConfig.aggregator`` / ``FLConfig.aggregator_kw``
(validated at construction, JSON round-trips through ``ExperimentSpec``
and the ``repro.fed.run`` CLI). The client-side attack components this
subsystem is measured against live in ``repro.fed.attacks``;
``benchmarks/robustness.py`` runs the accuracy-vs-attack-fraction grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lbgm import _block_layout
from repro.fed.registry import register_aggregator


class StreamingMean:
    """Marker rule: keep the engine's streaming weighted-mean fold.

    The engine checks ``streaming`` and routes to its existing
    ``DenseAggregator`` / ``SparseTopKAggregator`` — the exact pre-robust
    code path, so ``aggregator="mean"`` (the default) reproduces pre-PR
    round histories bit-for-bit on every scheduler.
    """

    streaming = True


def mask_invalid(w, g):
    """Zero out rows whose weight is <= 0, per leaf.

    Mirrors the streaming fold's ``w_k > 0`` gate: phantom pad clients run
    the loss on all-zero batches and may emit NaN/Inf updates that would
    poison a sort or a distance, even at zero weight.
    """
    def f(x):
        wcol = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(wcol > 0, x.astype(jnp.float32), 0.0)
    return jax.tree.map(f, g)


def _sorted_with_weights(w, x):
    """Sort one stacked leaf along the client axis, carrying weights.

    Returns ``(values, weights, cum_weights)`` each shaped like ``x``,
    sorted ascending per coordinate; ``cum_weights`` is the inclusive
    cumulative weight (total mass = sum(w)).
    """
    order = jnp.argsort(x, axis=0)
    v = jnp.take_along_axis(x, order, axis=0)
    wfull = jnp.broadcast_to(
        w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32), x.shape)
    ws = jnp.take_along_axis(wfull, order, axis=0)
    return v, ws, jnp.cumsum(ws, axis=0)


class TrimmedMean:
    """Per-coordinate weighted trimmed mean.

    For each coordinate, sort the K client values, drop ``beta`` weight
    mass from each tail of the (weighted) empirical distribution, and
    average what remains. ``beta=0`` is exactly the weighted mean;
    ``beta -> 0.5`` approaches the weighted median. Defined on weight
    mass (not client counts), so zero-weight clients never dilute the trim
    and non-uniform data-size weights are respected.
    """

    def __init__(self, beta: float = 0.1):
        if not 0.0 <= beta < 0.5:
            raise ValueError(
                f"trimmed_mean: beta must be in [0, 0.5), got {beta}")
        self.beta = float(beta)

    def reduce(self, w, g):
        g = mask_invalid(w, g)
        total = jnp.sum(w.astype(jnp.float32))
        lo, hi = self.beta * total, (1.0 - self.beta) * total

        def f(x):
            v, ws, cum = _sorted_with_weights(w, x)
            # effective mass of each sorted sample inside the [lo, hi]
            # weight window (0 for fully trimmed samples, partial at the
            # window edges) — the weighted generalization of "drop the
            # beta*K smallest and largest values"
            eff = jnp.clip(cum, lo, hi) - jnp.clip(cum - ws, lo, hi)
            return jnp.sum(eff * v, axis=0) / jnp.maximum(hi - lo, 1e-20)
        return jax.tree.map(f, g)


class CoordinateMedian:
    """Per-coordinate weighted median: the value at which the sorted
    per-coordinate distribution first crosses half the total weight."""

    def reduce(self, w, g):
        g = mask_invalid(w, g)
        half = 0.5 * jnp.sum(w.astype(jnp.float32))

        def f(x):
            v, _, cum = _sorted_with_weights(w, x)
            pick = jnp.argmax(cum >= half, axis=0)
            return jnp.take_along_axis(v, pick[None], axis=0)[0]
        return jax.tree.map(f, g)


class ScalarMedian:
    """O(K) robust rule exploiting the scalar-round payload structure.

    On a recycle round each client's whole update is ``rho_k * bank_k`` —
    one scalar of freedom per client. The generic robust rules ignore
    that structure: they densify every payload to the (K, nb, block)
    stack (O(K·M) peak) and run a coordinate-wise estimate. This rule
    instead takes the *weighted median of the K gscale scalars* (rho on a
    recycle round, exactly 1 on a full round — O(K log K) work on a (K,)
    vector) and folds the payloads with that single clipped multiplier:

        out = sum_k w_k * median(gscale) * payload_k

    so a Byzantine client cannot inflate its scalar-round contribution by
    lying about rho, while the fold itself stays the streaming-shaped
    O(K·k_frac·M) scatter-add — the stacks the schedulers collect are the
    raw sparse (idx, val) payloads, never densified
    (:class:`ScalarMedianSparseAggregator`). On full rounds every gscale
    is 1, the median is 1, and the rule degrades to the weighted mean.

    On rank-1 payload stacks (all clients sharing one bank direction)
    the geometric median of ``{rho_k * l}`` is exactly
    ``wmedian(rho) * l`` — the tolerance cross-check in the tests.
    """

    scalar_structured = True

    def median(self, w, gscale):
        """Weighted median of the per-client gscale scalars."""
        wf = w.astype(jnp.float32)
        gs = jnp.where(wf > 0, gscale.astype(jnp.float32), 0.0)
        v, _, cum = _sorted_with_weights(wf, gs)
        half = 0.5 * jnp.sum(wf)
        return v[jnp.argmax(cum >= half)]


class GeometricMedian:
    """Smoothed Weiszfeld geometric median over whole update vectors.

    ``iters`` fixed-point steps of z <- sum_k (w_k / max(||g_k - z||,
    eps)) g_k / sum_k (w_k / max(||g_k - z||, eps)), initialized at the
    weighted mean. The fixed iteration count keeps the round function
    static (jits, shards); ``eps`` smooths the reweighting so a client
    sitting exactly on the current estimate cannot blow up the weights
    (blades' AutoGM uses the same guard). Distances are accumulated
    leaf-wise in fp32 — no concatenated O(K·M) copy beyond the stack the
    collect mode already holds.
    """

    def __init__(self, iters: int = 8, eps: float = 1e-6):
        if iters < 1:
            raise ValueError(
                f"geometric_median: iters must be >= 1, got {iters}")
        if eps <= 0:
            raise ValueError(
                f"geometric_median: eps must be > 0, got {eps}")
        self.iters = int(iters)
        self.eps = float(eps)

    def reduce(self, w, g):
        g = mask_invalid(w, g)
        wf = w.astype(jnp.float32)

        def wavg(weights):
            denom = jnp.maximum(jnp.sum(weights), 1e-20)
            return jax.tree.map(
                lambda x: jnp.tensordot(weights, x, axes=1) / denom, g)

        def body(_, z):
            d2 = sum(
                jnp.sum((x - z[name][None]) ** 2,
                        axis=tuple(range(1, x.ndim)))
                for name, x in g.items())
            inv = wf / jnp.maximum(jnp.sqrt(d2), self.eps)
            # clients at zero weight contribute zero mass; the masked rows
            # are exact zeros so their distances are finite
            return wavg(inv)

        return jax.lax.fori_loop(0, self.iters, body, wavg(wf))


# ------------------------------------------------- engine collect adapters


class CollectDenseAggregator:
    """Collect-mode adapter over dense per-client g_tilde stacks.

    The schedulers hand :meth:`reduce` the full (K_padded, ...) stack of
    dense client updates plus the round's normalized weights; the wrapped
    rule turns it into one params-shaped aggregate.
    """

    collect = True
    sparse = False

    def __init__(self, rule):
        self.rule = rule

    def reduce(self, w, gt_stack):
        return self.rule.reduce(w, gt_stack)


class CollectSparseAggregator:
    """Collect-mode adapter over sparse (idx, val) scalar-round payloads.

    Each client's payload is densified into the bank's (nb, block) block
    layout with its ``gscale`` (rho on a recycle round, 1 on a full round)
    folded in — reconstructing exactly the g_tilde the streaming
    ``SparseTopKAggregator`` would have accumulated — and the stacked
    (K_padded, nb, block) views go through the wrapped rule
    coordinate-wise before the final reshape back to the params layout.
    Peak memory is O(K·M): a robust rule needs the cross-client view per
    coordinate, so the sparse wire format cannot stay sparse server-side.
    """

    collect = True
    sparse = True

    def __init__(self, rule, params, k_frac: float, decode=None,
                 payload_keys=("idx", "val")):
        self.rule = rule
        # wire-codec seam: quantized payloads carry {idx, val, scale}
        # leaves with wire-dtype values; ``decode`` widens them back to
        # fp32 (None = the values are fp32 already). payload_keys tells
        # the sharded scheduler the collect-stack leaf structure.
        self.decode = decode or (lambda sk: sk["val"])
        self.payload_keys = tuple(payload_keys)
        self._layout = {
            name: (leaf.shape, int(leaf.size))
            + _block_layout(int(leaf.size), k_frac)[:2]
            for name, leaf in params.items()}

    def reduce(self, w, out):
        send, gscale = out  # leaves (K, nb, kb); gscale (K,)

        def densify(name, sk):
            _, _, nb, block = self._layout[name]
            vals = self.decode(sk).astype(jnp.float32)

            def one(idx, val, s):
                dense = jnp.zeros((nb, block), jnp.float32)
                return jnp.put_along_axis(dense, idx, s * val, axis=1,
                                          inplace=False)
            return jax.vmap(one)(sk["idx"], vals,
                                 gscale.astype(jnp.float32))

        stacks = {name: densify(name, sk) for name, sk in send.items()}
        red = self.rule.reduce(w, stacks)
        return {name: red[name].reshape(-1)[:size].reshape(shape)
                for name, (shape, size, _, _) in self._layout.items()}


class ScalarMedianSparseAggregator:
    """Collect adapter for :class:`ScalarMedian` — O(K·k_frac·M) peak.

    The schedulers still stack the per-client payloads (collect mode),
    but the stacks stay in the sparse (idx, val[, scale]) wire layout:
    the rule's weighted median runs on the (K,) gscale vector alone, and
    the fold is the same strictly sequential gather-modify-scatter as the
    streaming :class:`~repro.fed.engine.SparseTopKAggregator` with
    ``gscale_k`` replaced by the one median — never a (K, nb, block)
    densified stack.
    """

    collect = True
    sparse = True

    def __init__(self, rule, params, k_frac: float, decode=None,
                 payload_keys=("idx", "val")):
        self.rule = rule
        self.decode = decode or (lambda sk: sk["val"])
        self.payload_keys = tuple(payload_keys)
        self._layout = {
            name: (leaf.shape, int(leaf.size))
            + _block_layout(int(leaf.size), k_frac)[:2]
            for name, leaf in params.items()}

    def reduce(self, w, out):
        send, gscale = out  # leaves (K, nb, kb); gscale (K,)
        med = self.rule.median(w, gscale)
        acc = {name: jnp.zeros((nb, block), jnp.float32)
               for name, (_, _, nb, block) in self._layout.items()}

        def body(a, x):
            w_k, send_k = x
            coeff = w_k * med

            def upd(ai, sk):
                rows = jnp.arange(ai.shape[0])[:, None]
                val = self.decode(sk).astype(jnp.float32)
                cur = ai[rows, sk["idx"]]
                new = cur + jnp.where(w_k > 0, coeff * val, 0.0)
                return ai.at[rows, sk["idx"]].set(new)

            return {name: upd(a[name], send_k[name]) for name in a}, None

        acc, _ = jax.lax.scan(body, acc, (w, send))
        return {name: acc[name].reshape(-1)[:size].reshape(shape)
                for name, (shape, size, _, _) in self._layout.items()}


# ------------------------------------------------------------ registry

# kw= declares each rule's aggregator_kw surface (the factories are
# lambdas over cfg, so Registry.valid_kw can't introspect them) — it is
# what lets FLConfig reject a typo'd key at construction
register_aggregator("mean", lambda cfg: StreamingMean(), kw=())
register_aggregator("trimmed_mean", kw=("beta",))(
    lambda cfg: TrimmedMean(**(cfg.aggregator_kw or {})))
register_aggregator("coordinate_median", aliases=("median",), kw=())(
    lambda cfg: CoordinateMedian(**(cfg.aggregator_kw or {})))
register_aggregator("geometric_median", aliases=("gm",),
                    kw=("iters", "eps"))(
    lambda cfg: GeometricMedian(**(cfg.aggregator_kw or {})))
register_aggregator("scalar_median", kw=())(
    lambda cfg: ScalarMedian(**(cfg.aggregator_kw or {})))


def make_robust_rule(cfg):
    """Resolve ``cfg.aggregator`` through the registry, with an
    actionable error when ``aggregator_kw`` doesn't match the rule."""
    from repro.fed.registry import AGGREGATORS
    try:
        return AGGREGATORS.get(cfg.aggregator)(cfg)
    except TypeError as e:
        raise ValueError(
            f"FLConfig.aggregator_kw {cfg.aggregator_kw!r} does not match "
            f"aggregator {cfg.aggregator!r}: {e}") from e
