"""Paper-faithful FL runtime (Algorithms 1 & 3) for the small paper-native
models — drives the benchmark reproductions of Figs. 5-8.

100 workers, tau local SGD steps, optional device sampling, optional base
compressor (top-K / ATOMO / SignSGD) under LBGM (plug-and-play P3/P4), with
error feedback when top-K is active. Everything is one jit'd round function
(clients vmapped); uplink accounting follows the paper's metric of
floating-point parameters shared per worker.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import get_compressor
from repro.compression import error_feedback as ef
from repro.core import lbgm as lbgm_lib
from repro.core.tree_math import tree_size, tree_zeros_like


@dataclass
class FLConfig:
    num_clients: int = 100
    tau: int = 2                     # local SGD steps per round
    lr: float = 0.05
    batch_size: int = 32
    use_lbgm: bool = True
    delta_threshold: float = 0.2
    compressor: str = "none"         # none | topk | atomo | signsgd
    compressor_kw: Optional[dict] = None
    error_feedback: Optional[bool] = None   # default: on iff topk
    sample_frac: float = 1.0         # Algorithm 3 device sampling
    seed: int = 0


class FLSystem:
    """loss_fn(params, batch_dict) -> (loss, metrics). Data is a list of
    per-client dicts of numpy arrays (see repro.fed.partition)."""

    def __init__(self, loss_fn: Callable, params: Dict[str, jax.Array],
                 client_data: List[Dict[str, np.ndarray]], flcfg: FLConfig):
        self.loss_fn = loss_fn
        self.cfg = flcfg
        self.params = params
        self.key = jax.random.PRNGKey(flcfg.seed)
        self.client_data = client_data
        K = flcfg.num_clients
        assert len(client_data) == K
        self.weights = np.array([len(next(iter(d.values())))
                                 for d in client_data], np.float64)
        self.weights = jnp.asarray(self.weights / self.weights.sum(),
                                   jnp.float32)
        self.lbg = jax.tree.map(
            lambda p: jnp.zeros((K,) + p.shape, p.dtype), params) \
            if flcfg.use_lbgm else None
        use_ef = (flcfg.error_feedback if flcfg.error_feedback is not None
                  else flcfg.compressor == "topk")
        self.residual = jax.tree.map(
            lambda p: jnp.zeros((K,) + p.shape, jnp.float32), params) \
            if use_ef and flcfg.compressor != "none" else None
        self._use_ef = self.residual is not None
        self._round = jax.jit(self._build_round())
        self.total_uplink = 0.0
        self.vanilla_uplink = 0.0
        self.history: List[Dict[str, float]] = []

    # -------------------------------------------------------------- build
    def _build_round(self):
        cfg = self.cfg
        loss_fn = self.loss_fn
        compress = get_compressor(cfg.compressor, **(cfg.compressor_kw or {}))
        M = float(tree_size(self.params))

        def client_update(params, batches):
            """tau local steps; batches: dict leaves (tau, b, ...)."""
            def step(p, bt):
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, bt)
                p2 = jax.tree.map(
                    lambda x, gg: x - cfg.lr * gg.astype(x.dtype), p, g)
                return p2, (g, l)
            _, (gs, ls) = jax.lax.scan(step, params, batches)
            asg = jax.tree.map(lambda g: jnp.sum(g, 0), gs)
            return asg, jnp.mean(ls)

        def one_client(params, batches, lbg_k, resid_k):
            asg, loss = client_update(params, batches)
            cost = jnp.asarray(M, jnp.float32)
            if cfg.compressor != "none":
                if self._use_ef:
                    asg, resid_k, cost = ef.apply(compress, asg, resid_k)
                else:
                    asg, cost = compress(asg)
            if cfg.use_lbgm:
                gt, lbg_k, stats = lbgm_lib.lbgm_client_step(
                    asg, lbg_k, cfg.delta_threshold)
                # scalar rounds upload 1 float; full rounds pay the base cost
                uplink = jnp.where(stats.sent_scalar, 1.0, cost)
                scalar = stats.sent_scalar
            else:
                gt, uplink, scalar = asg, cost, jnp.asarray(False)
            return gt, lbg_k, resid_k, loss, uplink, scalar

        def round_fn(params, lbg, residual, batch, mask):
            """batch leaves: (K, tau, b, ...); mask: (K,) participation."""
            lbg_in = lbg if lbg is not None else tree_zeros_like(params)
            res_in = residual
            K = cfg.num_clients
            if lbg is None:
                lbg_in = jax.tree.map(
                    lambda p: jnp.zeros((K,) + p.shape, p.dtype), params)
            if residual is None:
                res_in = jax.tree.map(
                    lambda p: jnp.zeros((K,) + p.shape, jnp.float32), params)
            gt, new_lbg, new_res, losses, uplink, scalar = jax.vmap(
                lambda b, l, r: one_client(params, b, l, r))(
                    batch, lbg_in, res_in)
            maskf = mask.astype(jnp.float32)
            w = self.weights * maskf
            w = w / jnp.maximum(jnp.sum(w), 1e-12)
            agg = jax.tree.map(
                lambda g: jnp.einsum("k,k...->...", w,
                                     g.astype(jnp.float32)), gt)
            new_params = jax.tree.map(
                lambda p, a: p - cfg.lr * a.astype(p.dtype), params, agg)
            # unsampled clients keep their previous LBG / residual
            keep = lambda new, old: jax.tree.map(
                lambda n, o: jnp.where(
                    maskf.reshape((-1,) + (1,) * (n.ndim - 1)) > 0, n, o),
                new, old)
            new_lbg = keep(new_lbg, lbg_in)
            new_res = keep(new_res, res_in)
            metrics = {
                "loss": jnp.sum(losses * w),
                "uplink_floats": jnp.sum(uplink * maskf),
                "frac_scalar": jnp.sum(scalar.astype(jnp.float32) * maskf)
                / jnp.maximum(jnp.sum(maskf), 1.0),
            }
            return new_params, new_lbg, new_res, metrics

        return round_fn

    # -------------------------------------------------------------- data
    def _sample_batches(self, rng: np.random.RandomState):
        cfg = self.cfg
        out = None
        for d in self.client_data:
            n = len(next(iter(d.values())))
            idx = rng.randint(0, n, size=(cfg.tau, cfg.batch_size))
            picked = {k: v[idx] for k, v in d.items()}
            if out is None:
                out = {k: [] for k in picked}
            for k, v in picked.items():
                out[k].append(v)
        return {k: jnp.asarray(np.stack(v)) for k, v in out.items()}

    # -------------------------------------------------------------- run
    def run_round(self, rng: np.random.RandomState) -> Dict[str, float]:
        cfg = self.cfg
        batch = self._sample_batches(rng)
        mask = (rng.rand(cfg.num_clients) < cfg.sample_frac) \
            if cfg.sample_frac < 1.0 else np.ones(cfg.num_clients)
        if mask.sum() == 0:
            mask[rng.randint(cfg.num_clients)] = 1
        self.params, self.lbg, self.residual, metrics = self._round(
            self.params, self.lbg, self.residual, batch,
            jnp.asarray(mask, jnp.float32))
        m = {k: float(v) for k, v in metrics.items()}
        self.total_uplink += m["uplink_floats"]
        self.vanilla_uplink += float(mask.sum()) * tree_size(self.params)
        m["total_uplink"] = self.total_uplink
        m["vanilla_uplink"] = self.vanilla_uplink
        m["savings"] = 1.0 - self.total_uplink / max(self.vanilla_uplink, 1.0)
        self.history.append(m)
        return m

    def run(self, rounds: int, eval_fn: Optional[Callable] = None,
            eval_every: int = 10, verbose: bool = False):
        rng = np.random.RandomState(self.cfg.seed + 1)
        for r in range(rounds):
            m = self.run_round(rng)
            if eval_fn is not None and (r + 1) % eval_every == 0:
                m.update(eval_fn(self.params))
            if verbose and (r + 1) % eval_every == 0:
                print(f"round {r+1:4d} " +
                      " ".join(f"{k}={v:.4g}" for k, v in m.items()))
        return self.history
