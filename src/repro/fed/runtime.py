"""Back-compat shim — the FL runtime now lives in ``repro.fed.engine``.

``FLSystem`` predates the unified engine (pluggable client schedulers +
LBGStore abstraction) and the declarative experiment API; constructing it
now emits a :class:`DeprecationWarning` and routes through the same
validated ``FLConfig`` + registry path as ``FLEngine``, so legacy callers
and checkpoints of the original all-clients-vmapped runtime keep working.
New code should describe the run as an
:class:`~repro.fed.experiment.ExperimentSpec` and use ``run_experiment``
(or construct ``repro.fed.engine.FLEngine`` directly when hand-wiring).
"""
from __future__ import annotations

import warnings

from repro.fed.engine import FLConfig, FLEngine  # noqa: F401


class FLSystem(FLEngine):
    """Deprecated alias for :class:`repro.fed.engine.FLEngine`."""

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "repro.fed.runtime.FLSystem is deprecated; build an "
            "ExperimentSpec and call repro.fed.run_experiment (or use "
            "repro.fed.engine.FLEngine directly)",
            DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)
