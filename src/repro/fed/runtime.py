"""Back-compat shim — the FL runtime now lives in ``repro.fed.engine``.

``FLSystem`` predates the unified engine (pluggable client schedulers +
LBGStore abstraction); it is kept as a thin alias so existing callers and
checkpoints of the original all-clients-vmapped runtime keep working.
New code should construct ``repro.fed.engine.FLEngine`` directly.
"""
from __future__ import annotations

from repro.fed.engine import FLConfig, FLEngine  # noqa: F401


class FLSystem(FLEngine):
    """Deprecated alias for :class:`repro.fed.engine.FLEngine`."""
