"""Canonical FL/LBGM knob container — the single source of truth.

``FLConfig`` is the one place the paper's Algorithm 1/3 knobs
(``delta_threshold``, ``k_frac`` via ``lbg_kw``, ``num_clients``,
``sample_frac``, ``tau``) and the engine's execution knobs (scheduler,
chunking, compressor pipeline) are defined. The arch-side view
``repro.configs.base.LBGMConfig`` is a thin shim over this class (its
shared defaults are read from ``FLConfig``'s fields and it converts via
``LBGMConfig.to_fl()`` / ``FLConfig.from_lbgm()``), so the two can no
longer drift.

``fused_kernels`` gates the engine's fused decision hot path (one-pass
Pallas projection/decision kernels + sparse scalar-round aggregation);
like every other field it is a plain JSON value (``None``/``true``/
``false``) and round-trips losslessly through ``to_dict``/``from_dict``
and any ``ExperimentSpec`` embedding it.

Every field is validated at construction (not at ``FLEngine.__init__``):
registry-keyed fields (``scheduler``, ``lbg_variant``, ``compressor``)
are checked against the live registries and the error lists the
registered names, so a typo fails immediately with the fix in the
message. The dataclass is frozen so an :class:`ExperimentSpec` embedding
it is immutable and safely shareable across sweep points.

This module stays import-light (no jax): registries are consulted
lazily, which also lets ``repro.configs`` import it without cycles.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

#: legacy spelling used by the arch-side LBGMConfig ("full" dense bank)
_LBG_VARIANT_ALIASES = {"full": "dense"}


@dataclass(frozen=True)
class FLConfig:
    num_clients: int = 100
    tau: int = 2                     # local SGD steps per round
    lr: float = 0.05
    batch_size: int = 32
    use_lbgm: bool = True
    delta_threshold: float = 0.2
    compressor: str = "none"         # registry key: none | topk | atomo | ...
    compressor_kw: Optional[dict] = None
    error_feedback: Optional[bool] = None   # default: on iff topk
    sample_frac: float = 1.0         # Algorithm 3 device sampling
    seed: int = 0
    scheduler: str = "vmap"          # registry key: vmap | chunked | ...
    chunk_size: int = 16             # max clients per lax.scan block
    mesh: Union[None, int, list] = None
    # ^ "sharded" scheduler: the 2-D (clients, model) device-mesh spec,
    #   resolved to a live named Mesh by ``launch.mesh.make_fl_mesh``.
    #   Three JSON-able spellings, all lossless through to_dict/from_dict:
    #     None     -> every local device on the client axis: (n_local, 1)
    #     int n    -> (n, 1) — the pre-2-D spelling; existing specs/CLIs
    #                 round-trip unchanged and run bit-for-bit identically
    #     [c, m]   -> c-way client-data-parallel x m-way model-axis
    #                 sharding of the LBG decision/banks (tuples are
    #                 normalized to lists so equality survives a JSON trip)
    model_sharding: str = "replicate"
    # ^ "sharded" scheduler: how each client's local-SGD forward/backward
    #   lays the MODEL out over the mesh's model axis.
    #     "replicate" (default) — every device holds the full params; only
    #                 the LBG bank / decision / aggregation rows shard over
    #                 ``model`` (bit-for-bit today's engine on every mesh).
    #     "auto"     — the model component's logical-axis tree (see
    #                 ``fed.experiment`` — the "lm" component carries its
    #                 arch's real axes) is resolved against the mesh via
    #                 ``train.sharding.param_pspec`` and the per-client
    #                 forward/backward runs tensor-parallel under GSPMD:
    #                 per-device params + activations scale as ~M/m, and
    #                 gradients arrive already laid out for the
    #                 model-sharded bank/decision path (fp32-tolerance
    #                 equal to "replicate", identical uplink accounting).
    #                 Requires scheduler="sharded", a metadata-carrying
    #                 model component, lbg_variant="topk-sharded" with
    #                 sparse aggregation, aggregator="mean", and
    #                 compressor="none" (validated at engine build).
    lbg_variant: str = "dense"       # registry key: dense | topk | null | ...
    lbg_kw: Optional[dict] = None    # e.g. {"k_frac": 0.1} for topk
    aggregator: str = "mean"         # registry key: mean | trimmed_mean |
    #   coordinate_median | geometric_median | ... "mean" (default) keeps
    #   the engine's streaming weighted-mean fold — bit-for-bit the
    #   pre-robustness round history on every scheduler; every other rule
    #   is Byzantine-robust and switches the schedulers into collect mode
    #   (per-client payload stacks, O(K·M) peak — see repro.fed.robust).
    aggregator_kw: Optional[dict] = None   # e.g. {"beta": 0.1} | {"iters": 8}
    attack: Optional[str] = None     # registry key: sign_flip | scaled |
    #   free_rider | gaussian | label_flip | ... None = no attack (default).
    attack_frac: float = 0.0         # fraction of clients made Byzantine
    #   (a fixed round(attack_frac*K) cohort, drawn deterministically from
    #   the seed — see repro.fed.attacks.select_byzantine)
    attack_kw: Optional[dict] = None       # e.g. {"sigma": 2.0} for gaussian
    dropout_frac: float = 0.0        # straggler fault injection: per round,
    #   each sampled client independently drops out with this probability
    #   (rides the participation-mask path; draws come from the dedicated
    #   fault stream, so the batch/mask rng stream is untouched)
    fused_kernels: Optional[bool] = None
    # ^ the LBGM decision hot path. None (default) = auto: sparse
    #   scalar-round aggregation wherever the LBG store supports it (any
    #   backend) + one-pass Pallas decision kernels on TPU only (XLA
    #   fallback elsewhere). True forces the Pallas kernels on too
    #   (interpret mode off-TPU — for testing). False = the legacy dense
    #   path: per-client dense g_tilde scatter + 3-pass XLA decision,
    #   bit-for-bit identical to pre-knob round histories. Plain
    #   Optional[bool], so specs stay JSON-able and round-trip losslessly.
    codec: str = "none"              # registry key: none | delta_idx |
    #   int8 | fp8 — the uplink wire codec (repro.comm.wire). "none"
    #   (default) keeps the fp32 wire format and the pre-codec round
    #   history bit-for-bit; delta_idx compresses the sparse index stream
    #   losslessly; int8/fp8 stochastically quantize payload values and
    #   the scalar-round rho stream. Every codec feeds the real-byte
    #   ``wire_bytes`` ledger alongside the fp32-scalar counters.
    codec_kw: Optional[dict] = None  # e.g. {"stochastic": False} to pin
    #   nearest rounding for int8/fp8 (see repro.comm.wire)
    latency: str = "none"            # registry key: none | fixed | uniform |
    #   lognormal | straggler — the per-client rounds-of-delay model for
    #   scheduler="buffered" (repro.fed.latency). "none" (default) keeps
    #   every payload synchronous; any other model draws deterministic
    #   per-round delays from the dedicated fault stream, so the async
    #   replay is seed-exact and clean runs stay bit-for-bit untouched.
    latency_kw: Optional[dict] = None      # e.g. {"frac": 0.2, "delay": 4}
    #   for straggler, {"scale": 2.0} for lognormal; alpha sets the
    #   staleness discount 1/(1+s)^alpha every model carries;
    #   {"max_staleness": s} (any model) evicts-and-drops buffered
    #   payloads older than s rounds (counted in CommLedger.n_evicted)
    tiers: Union[None, list, dict] = None
    # ^ hierarchical aggregation tier map (repro.fed.hierarchy). None
    #   (default) = the flat single-server fold. Two JSON-able spellings:
    #     [e] or [e, r]            -> e edge servers (and optionally r
    #                                 regions) with contiguous balanced
    #                                 client assignment in client order
    #     {"levels": [e, r],       -> same levels, but "shuffle" derives a
    #      "assign": "shuffle"}       seed-dependent client permutation
    #   Clients fold into per-edge partial carries, edges into regions,
    #   regions into the global update. The global result is bit-for-bit
    #   the flat fold (the flat carry is kept alongside — see
    #   fed/hierarchy.py), and CommLedger attributes per-tier wire bytes:
    #   edge links carry the clients' sparse payloads, region/global
    #   links carry one dense partial-carry model each — the real comms
    #   saving at scale. Not supported with scheduler='sharded' (the
    #   wrapped carry pytree breaks the mesh partition specs).
    ckpt_every: int = 0              # checkpoint cadence in rounds; 0 = off.
    #   Every N completed rounds the engine atomically snapshots params +
    #   LBG banks + residuals + rng streams + buffered in-flight slots +
    #   the CommLedger to ckpt_path (repro.checkpoint.ckpt), and
    #   ``repro.fed.run --resume`` / ``FLEngine.run(resume=True)``
    #   continues a run from it bit-for-bit mid-stream.
    ckpt_path: Optional[str] = None  # .npz checkpoint target path

    # ---------------------------------------------------------- validation
    def __post_init__(self):
        def bad(msg):
            raise ValueError(f"FLConfig: {msg}")

        if self.num_clients < 1:
            bad(f"num_clients must be >= 1, got {self.num_clients}")
        if self.tau < 1:
            bad(f"tau must be >= 1, got {self.tau}")
        if self.batch_size < 1:
            bad(f"batch_size must be >= 1, got {self.batch_size}")
        if not 0.0 < self.sample_frac <= 1.0:
            bad(f"sample_frac must be in (0, 1], got {self.sample_frac}")
        if self.chunk_size < 1:
            bad(f"chunk_size must be >= 1, got {self.chunk_size}")
        # mesh stays a plain JSON value (None, int, or a 2-list) so the
        # config — and any ExperimentSpec embedding it — remains
        # JSON-serializable; the sharded scheduler resolves it to a live
        # 2-D (clients, model) Mesh at engine build. bools are ints in
        # Python, so reject them explicitly.
        def int_ge1(x):
            return isinstance(x, int) and not isinstance(x, bool) and x >= 1
        if self.mesh is not None:
            if isinstance(self.mesh, (list, tuple)):
                if len(self.mesh) != 2 or not all(int_ge1(d)
                                                  for d in self.mesh):
                    bad("mesh must be None, a client-device count >= 1, or "
                        "a [clients, model] pair of device counts >= 1 — "
                        f"got {self.mesh!r}")
                # canonicalize to a list: to_dict/JSON round-trips compare
                # equal no matter which sequence type the caller used
                object.__setattr__(self, "mesh", [int(d) for d in self.mesh])
            elif not int_ge1(self.mesh):
                bad("mesh must be None, a client-device count >= 1, or a "
                    f"[clients, model] pair — got {self.mesh!r}")
        if self.mesh_model_dim > 1 and self.scheduler in ("vmap", "chunked",
                                                          "buffered"):
            bad(f"mesh={self.mesh!r} asks for model-axis sharding but "
                f"scheduler={self.scheduler!r} is mesh-unaware; use "
                "scheduler='sharded' (the only built-in that runs the 2-D "
                "(clients, model) mesh)")
        if self.model_sharding not in ("replicate", "auto"):
            bad("model_sharding must be 'replicate' (every device holds "
                "the full params — today's engine) or 'auto' (tensor-"
                "parallel client compute from the model component's axis "
                f"metadata) — got {self.model_sharding!r}")
        if self.model_sharding == "auto" and self.scheduler != "sharded":
            bad(f"model_sharding='auto' shards the client forward/backward "
                "over the 2-D (clients, model) mesh, which only "
                f"scheduler='sharded' runs — got "
                f"scheduler={self.scheduler!r}")
        # identity check, not `in`: 0/1 compare == to False/True but would
        # silently miss the `is not False` gate in the engine's aggregator
        # selection — reject them with the fix in the message
        if not any(self.fused_kernels is v for v in (None, True, False)):
            bad("fused_kernels must be None (auto: Pallas on TPU, sparse "
                "aggregation everywhere), true, or false (legacy dense "
                f"path) — got {self.fused_kernels!r}; JSON/CLI specs must "
                "use the boolean literals, not 0/1")
        # robustness knobs: fractions in range, attack_frac only with an
        # attack named, kw dicts actually dicts
        if not 0.0 <= self.attack_frac <= 1.0:
            bad(f"attack_frac must be in [0, 1], got {self.attack_frac}")
        if not 0.0 <= self.dropout_frac < 1.0:
            bad(f"dropout_frac must be in [0, 1), got {self.dropout_frac}")
        if self.attack is None and self.attack_frac > 0:
            bad(f"attack_frac={self.attack_frac} but attack=None — name an "
                "attack (e.g. attack='sign_flip') or set attack_frac=0")
        for kw_name in ("aggregator_kw", "attack_kw", "codec_kw",
                        "latency_kw"):
            kw = getattr(self, kw_name)
            if kw is not None and not isinstance(kw, dict):
                bad(f"{kw_name} must be a dict or None, got {kw!r}")
        # buffered scheduler: latency models only make sense there, and
        # the scheduler itself folds sparse (idx, val) payload stacks
        # through the staleness buffer — it has no dense/legacy path
        if self.latency != "none" and self.scheduler != "buffered":
            bad(f"latency={self.latency!r} models rounds-of-delay for the "
                "buffered scheduler, but "
                f"scheduler={self.scheduler!r} folds every payload the "
                "round it is computed — use scheduler='buffered' or "
                "latency='none'")
        if self.scheduler == "buffered":
            if not self.use_lbgm or self.resolved_lbg_variant not in (
                    "topk", "topk-sharded"):
                bad("scheduler='buffered' buffers each client's sparse "
                    "(idx, val) payload between dispatch and delivery, "
                    "which needs the top-k LBG store — set use_lbgm=True "
                    "and lbg_variant='topk' (or 'topk-sharded'), got "
                    f"use_lbgm={self.use_lbgm} "
                    f"lbg_variant={self.lbg_variant!r}")
            if self.fused_kernels is False:
                bad("scheduler='buffered' requires the sparse aggregation "
                    "contract; fused_kernels=False selects the legacy "
                    "dense fold which cannot buffer payloads — leave "
                    "fused_kernels unset (auto) or True")
            if self.model_sharding != "replicate":
                bad("scheduler='buffered' runs the replicated chunked "
                    "layout; model_sharding="
                    f"{self.model_sharding!r} needs scheduler='sharded'")
        # topk-host keeps banks host-resident and streams them chunk-wise,
        # which only the chunked scheduler's fixed client-block layout
        # supports; dense residuals (error feedback) would reintroduce an
        # O(K, M) device tensor and defeat the point, so they are rejected
        if self.use_lbgm and self.resolved_lbg_variant == "topk-host":
            if self.scheduler != "chunked":
                bad("lbg_variant='topk-host' streams host-resident bank "
                    "chunks through the chunked client-block layout — set "
                    f"scheduler='chunked', got {self.scheduler!r}")
            ef_on = self.error_feedback is True or (
                self.error_feedback is None and self.compressor == "topk")
            if ef_on:
                bad("lbg_variant='topk-host' cannot run error feedback: "
                    "the dense (K, M) residual bank would live on device "
                    "and defeat out-of-core banks — set "
                    "error_feedback=False or compressor='none'")
            if self.fused_kernels is False:
                bad("lbg_variant='topk-host' requires the sparse "
                    "aggregation contract; fused_kernels=False selects "
                    "the legacy dense fold — leave fused_kernels unset "
                    "(auto) or True")
        # hierarchical tiers: validate the JSON spelling here (import-
        # light — the live TierMap is built at engine init)
        if self.tiers is not None:
            levels, assign = self.tiers, "contiguous"
            if isinstance(self.tiers, dict):
                unknown = set(self.tiers) - {"levels", "assign"}
                if unknown:
                    bad(f"tiers dict keys {sorted(unknown)} unknown; "
                        "valid keys: ['assign', 'levels']")
                levels = self.tiers.get("levels")
                assign = self.tiers.get("assign", "contiguous")
            if assign not in ("contiguous", "shuffle"):
                bad("tiers assign must be 'contiguous' or 'shuffle', "
                    f"got {assign!r}")
            if (not isinstance(levels, (list, tuple)) or
                    not 1 <= len(levels) <= 2 or
                    not all(int_ge1(n) for n in levels)):
                bad("tiers levels must be [n_edges] or "
                    "[n_edges, n_regions] with ints >= 1, got "
                    f"{levels!r}")
            levels = [int(n) for n in levels]
            if levels[0] > self.num_clients:
                bad(f"tiers asks for {levels[0]} edges but only "
                    f"{self.num_clients} clients exist")
            if len(levels) == 2 and levels[1] > levels[0]:
                bad(f"tiers levels must descend edge -> region, got "
                    f"{levels!r}")
            # canonicalize sequences to lists for JSON-trip equality
            if isinstance(self.tiers, dict):
                object.__setattr__(
                    self, "tiers", {"levels": levels, "assign": assign})
            else:
                object.__setattr__(self, "tiers", levels)
            if self.scheduler == "sharded":
                bad("tiers are not supported with scheduler='sharded': "
                    "the hierarchical carry pytree has no mesh partition "
                    "spec — use vmap/chunked/buffered")
        if self.ckpt_every < 0:
            bad(f"ckpt_every must be >= 0, got {self.ckpt_every}")
        if self.ckpt_every > 0 and not self.ckpt_path:
            bad(f"ckpt_every={self.ckpt_every} needs a ckpt_path to "
                "write to")
        # registry-keyed fields: fail now, with the registered names in the
        # message, instead of deep inside the engine build
        from repro.fed import registry as reg
        if self.scheduler not in reg.SCHEDULERS:
            bad(f"unknown scheduler {self.scheduler!r}; registered "
                f"schedulers: {reg.SCHEDULERS.names()}")
        if self.use_lbgm and self.resolved_lbg_variant not in reg.LBG_STORES:
            bad(f"unknown lbg_variant {self.lbg_variant!r}; registered "
                f"lbg_stores: {reg.LBG_STORES.names()}")
        if self.compressor not in reg.COMPRESSORS:
            bad(f"unknown compressor {self.compressor!r}; registered "
                f"compressors: {reg.COMPRESSORS.names()}")
        if self.aggregator not in reg.AGGREGATORS:
            bad(f"unknown aggregator {self.aggregator!r}; registered "
                f"aggregators: {reg.AGGREGATORS.names()}")
        if self.attack is not None and self.attack not in reg.ATTACKS:
            bad(f"unknown attack {self.attack!r}; registered "
                f"attacks: {reg.ATTACKS.names()}")
        if self.codec not in reg.CODECS:
            bad(f"unknown codec {self.codec!r}; registered "
                f"codecs: {reg.CODECS.names()}")
        if self.latency not in reg.LATENCIES:
            bad(f"unknown latency {self.latency!r}; registered "
                f"latency models: {reg.LATENCIES.names()}")
        # *_kw keys checked against the registered component's signature
        # (or its explicit kw= spec) — a typo'd key fails here with the
        # valid names, not as a TypeError deep inside the engine build.
        # valid_kw returns None for unintrospectable factories: skip.
        for field, kw_name, registry in (
                ("aggregator", "aggregator_kw", reg.AGGREGATORS),
                ("attack", "attack_kw", reg.ATTACKS),
                ("codec", "codec_kw", reg.CODECS),
                ("latency", "latency_kw", reg.LATENCIES)):
            comp, kw = getattr(self, field), getattr(self, kw_name)
            if comp is None or not kw:
                continue
            valid = registry.valid_kw(comp)
            if valid is None:
                continue
            unknown = sorted(set(kw) - valid)
            if unknown:
                bad(f"{kw_name} keys {unknown} are not accepted by "
                    f"{field}={comp!r}; valid keys: {sorted(valid)}")

    # ------------------------------------------------------------- views
    @property
    def resolved_lbg_variant(self) -> str:
        return _LBG_VARIANT_ALIASES.get(self.lbg_variant, self.lbg_variant)

    @property
    def mesh_shape(self) -> Optional[Tuple[int, int]]:
        """The (clients, model) mesh shape, or None for "every local
        device on the client axis" (resolved at engine build, where the
        device count is known). An int spec is exactly ``(n, 1)``."""
        if self.mesh is None:
            return None
        if isinstance(self.mesh, int):
            return (self.mesh, 1)
        return (self.mesh[0], self.mesh[1])

    @property
    def mesh_model_dim(self) -> int:
        """Model-axis extent of the mesh (1 unless a 2-D spec asks for
        model sharding) — importable without jax, so stores/validators can
        branch on it before any device exists."""
        shape = self.mesh_shape
        return 1 if shape is None else shape[1]

    def replace(self, **overrides) -> "FLConfig":
        return dataclasses.replace(self, **overrides)

    # ------------------------------------------------------ serialization
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FLConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"FLConfig: unknown fields {sorted(unknown)}; "
                f"known fields: {sorted(known)}")
        return cls(**d)

    # ------------------------------------------------- arch-config bridge
    @classmethod
    def from_lbgm(cls, lbgm, **overrides) -> "FLConfig":
        """Build from an arch-side ``configs.base.LBGMConfig`` view."""
        kw = dict(
            use_lbgm=lbgm.enabled,
            lbg_variant=lbgm.variant,
            delta_threshold=lbgm.delta_threshold,
            num_clients=lbgm.num_clients,
            tau=lbgm.local_steps,
            sample_frac=lbgm.sample_frac,
        )
        if _LBG_VARIANT_ALIASES.get(lbgm.variant, lbgm.variant) == "topk":
            kw["lbg_kw"] = {"k_frac": lbgm.k_frac}
        kw.update(overrides)
        return cls(**kw)
