"""Federated data partitioning: iid and label-skew non-iid (paper setup:
"each worker has training data only from a subset of all labels",
e.g. 3 of 10 classes)."""
from __future__ import annotations

from typing import List

import numpy as np


def partition_iid(n: int, num_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(idx, num_clients)]


def partition_label_skew(labels: np.ndarray, num_clients: int,
                         classes_per_client: int = 3,
                         seed: int = 0) -> List[np.ndarray]:
    """Each client sees only `classes_per_client` labels (non-iid S1)."""
    rng = np.random.RandomState(seed)
    num_classes = int(labels.max()) + 1
    by_class = [np.where(labels == c)[0] for c in range(num_classes)]
    for c in by_class:
        rng.shuffle(c)
    ptr = [0] * num_classes
    out = []
    for k in range(num_clients):
        classes = rng.choice(num_classes, classes_per_client, replace=False)
        take = []
        for c in classes:
            per = max(1, len(by_class[c]) * classes_per_client
                      // (num_clients * classes_per_client))
            lo = ptr[c] % max(len(by_class[c]) - per, 1)
            take.append(by_class[c][lo:lo + per])
            ptr[c] += per
        out.append(np.sort(np.concatenate(take)))
    return out
