"""Federated data partitioning: iid and label-skew non-iid (paper setup:
"each worker has training data only from a subset of all labels",
e.g. 3 of 10 classes)."""
from __future__ import annotations

from typing import List

import numpy as np


def partition_iid(n: int, num_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(idx, num_clients)]


def partition_label_skew(labels: np.ndarray, num_clients: int,
                         classes_per_client: int = 3,
                         seed: int = 0) -> List[np.ndarray]:
    """Each client sees only `classes_per_client` labels (non-iid S1).

    Client shards are pairwise DISJOINT and, for every class at least one
    client drew, they jointly COVER that class's whole pool: each client
    first draws its class subset, then every class's (shuffled) pool is
    dealt out contiguously across exactly the clients that drew it. A
    client's shard can only come up empty in the degenerate case where
    every one of its classes has fewer samples than clients sharing it
    (demand > supply).
    """
    rng = np.random.RandomState(seed)
    num_classes = int(labels.max()) + 1
    by_class = [np.where(labels == c)[0] for c in range(num_classes)]
    for c in by_class:
        rng.shuffle(c)
    # draw every client's class subset first so each class knows its takers
    choices = [rng.choice(num_classes, classes_per_client, replace=False)
               for _ in range(num_clients)]
    take: List[List[np.ndarray]] = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        takers = [k for k in range(num_clients) if c in choices[k]]
        if not takers:
            continue  # nobody drew this class; its pool stays unused
        for k, shard in zip(takers, np.array_split(by_class[c], len(takers))):
            take[k].append(shard)
    empty = np.array([], dtype=np.int64)
    return [np.sort(np.concatenate(t)) if t else empty for t in take]
