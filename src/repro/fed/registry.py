"""String-keyed component registries for the declarative experiment API.

Every pluggable piece of an FL experiment — model, dataset, partitioner,
uplink compressor, client scheduler, LBG storage scheme, server
aggregation rule, Byzantine attack — resolves through
one of the registries below, so an :class:`~repro.fed.experiment.ExperimentSpec`
can name components by string and round-trip through JSON, and third-party
code can extend the system without touching ``fed/engine.py``:

    from repro.fed import register_model

    @register_model("my-net")
    def build(seed=0, **kw):
        ...
        return params, loss_fn

This module is deliberately pure-Python (no jax, no repro imports) so any
layer may import it without dragging in the engine. Built-in components
live in jax-heavy modules (``repro.fed.engine``, ``repro.compression``,
``repro.fed.experiment``); each registry lazily imports its
``builtin_modules`` on first lookup so the built-ins are always visible
regardless of import order.
"""
from __future__ import annotations

import importlib
import inspect
from typing import Callable, Dict, FrozenSet, Iterable, Optional


class Registry:
    """A named string -> factory mapping with actionable error messages."""

    def __init__(self, kind: str, builtin_modules: Iterable[str] = ()):
        self.kind = kind
        self._entries: Dict[str, Callable] = {}
        self._aliases: Dict[str, str] = {}
        self._kw_specs: Dict[str, FrozenSet[str]] = {}
        self._builtin_modules = tuple(builtin_modules)
        self._loaded_modules: set = set()

    # ------------------------------------------------------------ loading
    def _ensure_builtins(self) -> None:
        # mark each module loaded only after its import succeeds: a failed
        # import must surface as the real ImportError on every lookup, not
        # latch the registry empty and report "registered: []". Re-entrancy
        # is safe — the imports call register(), never back into here.
        for mod in self._builtin_modules:
            if mod not in self._loaded_modules:
                importlib.import_module(mod)
                self._loaded_modules.add(mod)

    # -------------------------------------------------------- registration
    def register(self, name: str, obj: Optional[Callable] = None,
                 aliases: Iterable[str] = (),
                 kw: Optional[Iterable[str]] = None):
        """Register ``obj`` under ``name`` (usable as a decorator).

        Duplicate names are an error: silent overwrites are how two
        experiments end up silently running different code under one key.

        ``kw`` optionally declares the keyword names the component's
        ``*_kw`` config dict accepts — needed when the registered object
        is a factory (lambda over a cfg) whose signature hides the real
        constructor. Classes registered directly don't need it:
        :meth:`valid_kw` introspects their ``__init__``.
        """
        def _add(fn: Callable) -> Callable:
            # validate name AND all aliases before mutating anything, so a
            # collision leaves the registry untouched and the caller's
            # corrected retry succeeds
            if name in self._entries or name in self._aliases:
                raise ValueError(
                    f"duplicate {self.kind} registration {name!r}; "
                    f"registered: {self.names()}")
            for a in aliases:
                if a in self._entries or a in self._aliases:
                    raise ValueError(
                        f"duplicate {self.kind} alias {a!r}; "
                        f"registered: {self.names()}")
            self._entries[name] = fn
            for a in aliases:
                self._aliases[a] = name
            if kw is not None:
                self._kw_specs[name] = frozenset(kw)
            return fn
        return _add if obj is None else _add(obj)

    # ------------------------------------------------------------- lookup
    def get(self, name: str) -> Callable:
        self._ensure_builtins()
        key = self._aliases.get(name, name)
        try:
            return self._entries[key]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} {name!r}; registered "
                f"{self.kind}s: {self.names()}") from None

    def names(self) -> list:
        self._ensure_builtins()
        return sorted(self._entries)

    def valid_kw(self, name: str) -> Optional[FrozenSet[str]]:
        """Keyword names ``name``'s constructor accepts, or None when
        they can't be known statically (a factory registered without an
        explicit ``kw=`` spec, or a ``**kwargs`` constructor).

        ``FLConfig`` checks the user's ``*_kw`` dict against this at
        construction so a typo'd key fails with the valid names in the
        message instead of a TypeError deep inside the engine build.
        An explicit ``kw=`` spec always wins over introspection.
        """
        self._ensure_builtins()
        key = self._aliases.get(name, name)
        if key in self._kw_specs:
            return self._kw_specs[key]
        obj = self._entries.get(key)
        if obj is None or not inspect.isclass(obj):
            return None
        init = obj.__init__
        if init is object.__init__:
            return frozenset()
        try:
            sig = inspect.signature(init)
        except (TypeError, ValueError):
            return None
        params = list(sig.parameters.values())[1:]   # drop self
        if any(p.kind in (p.VAR_KEYWORD, p.VAR_POSITIONAL)
               for p in params):
            return None
        return frozenset(p.name for p in params)

    def __contains__(self, name: str) -> bool:
        self._ensure_builtins()
        return name in self._entries or name in self._aliases


MODELS = Registry("model", builtin_modules=("repro.fed.experiment",))
DATASETS = Registry("dataset", builtin_modules=("repro.fed.experiment",))
PARTITIONERS = Registry("partitioner",
                        builtin_modules=("repro.fed.experiment",))
COMPRESSORS = Registry("compressor", builtin_modules=("repro.compression",))
SCHEDULERS = Registry("scheduler", builtin_modules=("repro.fed.engine",))
LBG_STORES = Registry("lbg_store", builtin_modules=("repro.fed.engine",))
AGGREGATORS = Registry("aggregator", builtin_modules=("repro.fed.robust",))
ATTACKS = Registry("attack", builtin_modules=("repro.fed.attacks",))
CODECS = Registry("codec", builtin_modules=("repro.comm.wire",))
LATENCIES = Registry("latency", builtin_modules=("repro.fed.latency",))

register_model = MODELS.register
register_dataset = DATASETS.register
register_partitioner = PARTITIONERS.register
register_compressor = COMPRESSORS.register
register_scheduler = SCHEDULERS.register
register_lbg_store = LBG_STORES.register
register_aggregator = AGGREGATORS.register
register_attack = ATTACKS.register
register_codec = CODECS.register
register_latency = LATENCIES.register
