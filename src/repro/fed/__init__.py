from repro.fed.partition import partition_iid, partition_label_skew  # noqa: F401
from repro.fed.runtime import FLConfig, FLSystem  # noqa: F401
