from repro.fed.engine import (DenseLBGStore, FLConfig, FLEngine,  # noqa: F401
                              NullLBGStore, TopKLBGStore, make_lbg_store)
from repro.fed.partition import partition_iid, partition_label_skew  # noqa: F401
from repro.fed.runtime import FLSystem  # noqa: F401
