"""Federated learning package: engine, declarative experiment API, shims.

Attribute access is lazy (PEP 562) so light modules — ``repro.fed.registry``
and ``repro.fed.flconfig`` are pure-Python — can be imported from any layer
(``repro.compression`` registers its pipelines, ``repro.configs.base``
derives its LBGM knob defaults) without this package eagerly pulling in the
jax-heavy engine and creating an import cycle.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # canonical config
    "FLConfig": "repro.fed.flconfig",
    # engine + pluggable pieces
    "FLEngine": "repro.fed.engine",
    "DenseLBGStore": "repro.fed.engine",
    "NullLBGStore": "repro.fed.engine",
    "ShardedTopKLBGStore": "repro.fed.engine",
    "TopKLBGStore": "repro.fed.engine",
    "make_lbg_store": "repro.fed.engine",
    "make_scheduler": "repro.fed.engine",
    "make_aggregator": "repro.fed.engine",
    "DenseAggregator": "repro.fed.engine",
    "SparseTopKAggregator": "repro.fed.engine",
    "RoundPrefetcher": "repro.fed.engine",
    "resolve_fused_kernels": "repro.fed.engine",
    # declarative experiment API
    "ExperimentSpec": "repro.fed.experiment",
    "ComponentSpec": "repro.fed.experiment",
    "EvalPolicy": "repro.fed.experiment",
    "ExperimentResult": "repro.fed.experiment",
    "RoundRecord": "repro.fed.experiment",
    "build_experiment": "repro.fed.experiment",
    "run_experiment": "repro.fed.experiment",
    "sweep": "repro.fed.experiment",
    # registries
    "register_model": "repro.fed.registry",
    "register_dataset": "repro.fed.registry",
    "register_partitioner": "repro.fed.registry",
    "register_compressor": "repro.fed.registry",
    "register_scheduler": "repro.fed.registry",
    "register_lbg_store": "repro.fed.registry",
    "register_latency": "repro.fed.registry",
    # data partitioning
    "partition_iid": "repro.fed.partition",
    "partition_label_skew": "repro.fed.partition",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.fed' has no attribute {name!r}") from None
    return getattr(importlib.import_module(module), name)


def __dir__():
    return __all__
