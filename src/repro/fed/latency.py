"""Per-client latency / compute-heterogeneity models for ``"buffered"``.

The buffered scheduler (FedBuff-style, see ``repro.fed.engine``) treats a
slow client as *latency*, not absence: a dispatched payload sits in
flight for a model-drawn number of rounds and is folded into the global
update in the round it lands, discounted by a staleness weight. The
models below supply three pluggable pieces:

* ``sample_delays(rng, K)`` — per-round (K,) integer rounds-of-delay,
  drawn from the engine's dedicated *fault stream* (see
  ``repro.fed.attacks.fault_rng``) so clean/synchronous runs stay
  bit-for-bit untouched and an async run replays exactly under a seed.
  Models that need no randomness never touch ``rng`` — the stream is
  only consumed when the model actually draws.
* ``staleness_weight(s)`` — the server-side discount applied to a
  payload delivered ``s`` rounds after dispatch. The default is the
  FedBuff-style polynomial ``1 / (1 + s)**alpha``, gated with
  ``jnp.where`` so fresh payloads (``s == 0``) keep weight exactly
  ``1.0`` — that gate is what makes zero-latency buffered runs
  bit-for-bit equal to the chunked scheduler.
* ``sample_tau(K, tau)`` — optional per-client local-step budget
  (compute heterogeneity): slow clients run fewer local SGD steps
  instead of vanishing. ``None`` (the default) keeps every client at the
  configured ``tau`` and the engine's homogeneous local-update scan.

Config surface: ``FLConfig.latency`` / ``latency_kw`` (validated at
construction, JSON round-trips through ``ExperimentSpec`` and the CLI).
Extend with ``@register_latency``; constructors are introspected by
``Registry.valid_kw`` so unknown ``latency_kw`` keys fail at FLConfig
construction with the valid names in the message.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.fed.registry import LATENCIES, register_latency

#: sentinel delay for clients whose payload never arrives (the dropout
#: arm of the async benchmark) — far beyond any real experiment length
NEVER = 1 << 30


class LatencyModel:
    """Base: zero delay, polynomial staleness discount, homogeneous tau.

    ``max_staleness`` (all models) bounds how long a dispatched payload
    may sit in a client's one-slot buffer: at the start of each round any
    in-flight payload older than ``max_staleness`` rounds is evicted and
    dropped (counted in ``CommLedger.n_evicted``) and the slot is free to
    re-dispatch that same round. ``None`` (default) parks payloads
    indefinitely — the pre-eviction behaviour, under which a straggler
    ``drop=True`` payload (delay = :data:`NEVER`) pins its slot forever.
    """

    def __init__(self, alpha: float = 0.5,
                 max_staleness: Optional[int] = None):
        if alpha < 0:
            raise ValueError(f"latency alpha must be >= 0, got {alpha}")
        if max_staleness is not None and int(max_staleness) < 0:
            raise ValueError(f"latency max_staleness must be >= 0 or "
                             f"None, got {max_staleness}")
        self.alpha = float(alpha)
        self.max_staleness = (None if max_staleness is None
                              else int(max_staleness))

    def setup(self, num_clients: int, seed: int) -> None:
        """One-time hook (e.g. draw a fixed straggler cohort)."""

    def sample_delays(self, rng: np.random.RandomState,
                      num_clients: int) -> np.ndarray:
        """Per-round (K,) integer rounds-of-delay (0 = arrives same
        round, i.e. synchronous)."""
        return np.zeros(num_clients, np.int64)

    def staleness_weight(self, s):
        """Traced discount for a payload ``s`` rounds stale; must return
        exactly 1.0 at ``s == 0`` (the where-gate guarantees it bit-wise
        even when ``(1+s)**-alpha`` is not exact on a backend)."""
        import jax.numpy as jnp
        return jnp.where(s > 0, (1.0 + s) ** (-self.alpha), 1.0)

    def sample_tau(self, num_clients: int,
                   tau: int) -> Optional[np.ndarray]:
        """Optional fixed per-client local-step budget (int32 (K,) in
        [1, tau]) or None for the homogeneous scan."""
        return None


@register_latency("none")
class NoLatency(LatencyModel):
    """Synchronous: every dispatched payload arrives the same round."""


@register_latency("fixed")
class FixedLatency(LatencyModel):
    """Every client delivers exactly ``delay`` rounds after dispatch —
    the simplest model, and the one the wire-attribution tests pin."""

    def __init__(self, delay: int = 1, alpha: float = 0.5,
                 max_staleness: Optional[int] = None):
        super().__init__(alpha, max_staleness)
        if delay < 0:
            raise ValueError(f"fixed latency delay must be >= 0, "
                             f"got {delay}")
        self.delay = int(delay)

    def sample_delays(self, rng, num_clients):
        return np.full(num_clients, self.delay, np.int64)


@register_latency("uniform")
class UniformLatency(LatencyModel):
    """Delay ~ UniformInt[low, high] per client per round."""

    def __init__(self, low: int = 0, high: int = 3, alpha: float = 0.5,
                 max_staleness: Optional[int] = None):
        super().__init__(alpha, max_staleness)
        if not 0 <= low <= high:
            raise ValueError(f"uniform latency needs 0 <= low <= high, "
                             f"got low={low} high={high}")
        self.low, self.high = int(low), int(high)

    def sample_delays(self, rng, num_clients):
        return rng.randint(self.low, self.high + 1,
                           size=num_clients).astype(np.int64)


@register_latency("lognormal")
class LognormalLatency(LatencyModel):
    """Delay = floor(scale * LogNormal(0, sigma)), clipped to
    ``max_delay`` — the heavy-tailed rounds-of-delay shape real federated
    deployments report (a few very slow devices dominate the tail)."""

    def __init__(self, scale: float = 1.0, sigma: float = 0.75,
                 max_delay: int = 16, alpha: float = 0.5,
                 max_staleness: Optional[int] = None):
        super().__init__(alpha, max_staleness)
        if scale < 0 or sigma < 0 or max_delay < 0:
            raise ValueError(
                f"lognormal latency needs scale, sigma, max_delay >= 0, "
                f"got scale={scale} sigma={sigma} max_delay={max_delay}")
        self.scale, self.sigma = float(scale), float(sigma)
        self.max_delay = int(max_delay)

    def sample_delays(self, rng, num_clients):
        d = np.floor(self.scale * rng.lognormal(
            0.0, self.sigma, size=num_clients))
        return np.clip(d, 0, self.max_delay).astype(np.int64)


@register_latency("straggler")
class StragglerLatency(LatencyModel):
    """A fixed seed-derived cohort of round(frac*K) stragglers.

    Non-cohort clients deliver immediately; cohort clients deliver
    ``delay`` (+ UniformInt[0, jitter]) rounds late, run ``slow_tau``
    local steps when set (compute heterogeneity), or — with
    ``drop=True`` — never deliver at all (delay = :data:`NEVER`), which
    is exactly the "dropout forfeits the stragglers" baseline arm of
    ``benchmarks/async_heterogeneity.py``. ``cohort="head"`` pins the
    cohort to clients ``[0, n)`` instead of a random draw, which under
    ``partition_label_skew`` concentrates the forfeited label mass and
    makes the dropout-vs-buffered accuracy gap reproducible.
    """

    def __init__(self, frac: float = 0.2, delay: int = 4, jitter: int = 0,
                 slow_tau: Optional[int] = None, drop: bool = False,
                 cohort: str = "random", alpha: float = 0.5,
                 max_staleness: Optional[int] = None):
        super().__init__(alpha, max_staleness)
        if not 0.0 <= frac <= 1.0:
            raise ValueError(f"straggler frac must be in [0, 1], "
                             f"got {frac}")
        if delay < 0 or jitter < 0:
            raise ValueError(f"straggler delay/jitter must be >= 0, got "
                             f"delay={delay} jitter={jitter}")
        if slow_tau is not None and slow_tau < 1:
            raise ValueError(f"straggler slow_tau must be >= 1, "
                             f"got {slow_tau}")
        if cohort not in ("random", "head"):
            raise ValueError(f"straggler cohort must be 'random' or "
                             f"'head', got {cohort!r}")
        self.frac, self.delay, self.jitter = float(frac), int(delay), \
            int(jitter)
        self.slow_tau = None if slow_tau is None else int(slow_tau)
        self.drop = bool(drop)
        self.cohort = cohort
        self._slow = None

    def setup(self, num_clients, seed):
        # same dedicated-stream construction as select_byzantine, offset
        # so the straggler cohort is independent of the Byzantine one
        self._slow = np.zeros(num_clients, bool)
        n = int(round(self.frac * num_clients))
        if n:
            if self.cohort == "head":
                self._slow[:n] = True
            else:
                cr = np.random.RandomState(
                    (seed * 2654435761 + 97) % (2 ** 31))
                self._slow[cr.choice(num_clients, size=n,
                                     replace=False)] = True

    def sample_delays(self, rng, num_clients):
        d = np.zeros(num_clients, np.int64)
        if self.drop:
            d[self._slow] = NEVER
            return d
        base = np.full(num_clients, self.delay, np.int64)
        if self.jitter:
            # draw all K for stream invariance w.r.t. cohort membership
            base = base + rng.randint(0, self.jitter + 1,
                                      size=num_clients)
        d[self._slow] = base[self._slow]
        return d

    def sample_tau(self, num_clients, tau):
        if self.slow_tau is None:
            return None
        t = np.full(num_clients, tau, np.int32)
        t[self._slow] = min(self.slow_tau, tau)
        return t


def make_latency(cfg):
    """Resolve ``cfg.latency`` through the registry and run its one-time
    ``setup`` (cohort draws etc.) against the config's seed."""
    try:
        model = LATENCIES.get(cfg.latency)(**(cfg.latency_kw or {}))
    except TypeError as e:
        raise ValueError(
            f"FLConfig.latency_kw {cfg.latency_kw!r} does not match "
            f"latency model {cfg.latency!r}: {e}") from e
    model.setup(cfg.num_clients, cfg.seed)
    return model
