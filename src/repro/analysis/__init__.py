from repro.analysis import pca, roofline  # noqa: F401
