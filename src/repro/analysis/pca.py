"""Gradient-space PCA study (paper §2, Algorithm 2).

Stack the accumulated per-epoch gradients, SVD, and count components
explaining 95%/99% of variance (N95-PCA / N99-PCA); plus the two cosine
heat maps (actual-vs-principal, Fig. 2; consecutive actual, Fig. 3) that
motivate hypotheses (H1)/(H2).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np


def flatten_grad(tree) -> np.ndarray:
    return np.concatenate([np.asarray(x, np.float32).reshape(-1)
                           for x in jax.tree.leaves(tree)])


def n_pca(grads: np.ndarray, variance: float) -> int:
    """#components explaining `variance` of total (Algorithm 2,
    get_num_PCA_components): count singular values accounting for that
    fraction of the aggregated singular values."""
    if grads.shape[0] == 1:
        return 1
    s = np.linalg.svd(grads, compute_uv=False)
    cum = np.cumsum(s) / max(np.sum(s), 1e-30)
    return int(np.searchsorted(cum, variance) + 1)


def pca_directions(grads: np.ndarray, variance: float) -> np.ndarray:
    """Principal gradient directions (left-singular rows in gradient space)."""
    u, s, vt = np.linalg.svd(grads, full_matrices=False)
    cum = np.cumsum(s) / max(np.sum(s), 1e-30)
    k = int(np.searchsorted(cum, variance) + 1)
    return vt[:k]


def cosine_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    an = a / np.maximum(np.linalg.norm(a, axis=1, keepdims=True), 1e-30)
    bn = b / np.maximum(np.linalg.norm(b, axis=1, keepdims=True), 1e-30)
    return an @ bn.T


class GradientSpaceTracker:
    """Collects per-epoch accumulated gradients and reports N-PCA progression
    (the paper's Fig. 1 top row) plus the Fig. 2/3 heat maps."""

    def __init__(self, max_dim: int = 200_000, seed: int = 0):
        # random projection keeps the SVD tractable for larger models;
        # JL-style projection preserves the spectrum statistics we report.
        self.max_dim = max_dim
        self.seed = seed
        self._proj = None
        self.grads: List[np.ndarray] = []
        self.n95: List[int] = []
        self.n99: List[int] = []

    def add(self, grad_tree):
        g = flatten_grad(grad_tree)
        if g.size > self.max_dim:
            if self._proj is None:
                rng = np.random.RandomState(self.seed)
                idx = rng.choice(g.size, self.max_dim, replace=False)
                self._proj = np.sort(idx)   # coordinate subsampling
            g = g[self._proj]
        self.grads.append(g)
        mat = np.stack(self.grads)
        self.n95.append(n_pca(mat, 0.95))
        self.n99.append(n_pca(mat, 0.99))

    def matrix(self) -> np.ndarray:
        return np.stack(self.grads)

    def heatmaps(self, variance: float = 0.99
                 ) -> Tuple[np.ndarray, np.ndarray]:
        mat = self.matrix()
        pgd = pca_directions(mat, variance)
        return cosine_matrix(mat, pgd), cosine_matrix(mat, mat)

    def summary(self) -> Dict[str, object]:
        return {"epochs": len(self.grads), "n95": self.n95, "n99": self.n99,
                "n95_final": self.n95[-1] if self.n95 else 0,
                "n99_final": self.n99[-1] if self.n99 else 0}
