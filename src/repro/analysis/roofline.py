"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Terms (per chip; the compiled SPMD module is the per-device program):
    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

collective_bytes is NOT in cost_analysis(): we parse the optimized HLO and
sum operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (brief-specified).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device ICI bytes per collective kind, from optimized HLO text.

    Operand shapes are not printed inline in optimized HLO, so we use the
    RESULT shape R plus the replica-group size n with standard ring-collective
    traffic factors:
        all-reduce          2 R (n-1)/n     (reduce-scatter + all-gather)
        all-gather          R (n-1)/n       (R = gathered result)
        reduce-scatter      R (n-1)         (input = n R per device)
        all-to-all          R (n-1)/n
        collective-permute  R
    """
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(
            r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", stripped)
        if not m:
            continue
        result, kind = m.group(1), m.group(2)
        r_bytes = sum(_shape_bytes(d, s)
                      for d, s in _SHAPE_RE.findall(result))
        n = _group_size(stripped)
        if n <= 1:
            continue
        if kind == "all-reduce":
            traffic = 2.0 * r_bytes * (n - 1) / n
        elif kind in ("all-gather", "all-to-all"):
            traffic = r_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            traffic = float(r_bytes) * (n - 1)
        else:  # collective-permute
            traffic = float(r_bytes)
        out[kind] += traffic
        out["count"] += 1
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float
    compute_s: float = field(init=False)
    memory_s: float = field(init=False)
    collective_s: float = field(init=False)

    def __post_init__(self):
        self.compute_s = self.flops_per_device / PEAK_FLOPS
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.collective_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "hlo_flops_per_dev": self.flops_per_device,
            "hlo_bytes_per_dev": self.bytes_per_device,
            "coll_bytes_per_dev": self.collective_bytes_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops(cfg, shape_cfg, n_active_params: int) -> float:
    """6 * N_active * D (training) or 2 * N_active * D (inference)."""
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_active_params * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_active_params * tokens
    # decode: one token per sequence
    return 2.0 * n_active_params * shape_cfg.global_batch


def build_report(arch: str, shape: str, mesh_name: str, chips: int,
                 cost: Optional[dict], hlo_text: str,
                 model_flops_global: float) -> RooflineReport:
    cost = cost or {}
    coll = collective_bytes(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=float(coll["total"]),
        model_flops_global=model_flops_global,
    )
