"""Configuration system for the LBGM reproduction framework.

Every assigned architecture gets one module in this package exporting
``CONFIG``; the registry in ``__init__`` maps ``--arch`` ids to them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# canonical FL/LBGM knob container — LBGMConfig below is the arch-side
# *view* of it; shared defaults are read from FLConfig's fields so the two
# cannot drift (repro.fed.flconfig is pure-Python, safe to import here)
from repro.fed.flconfig import FLConfig

_FL_DEFAULTS = {f.name: f.default for f in dataclasses.fields(FLConfig)}


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # 0 => dense FFN
    top_k: int = 1
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01   # load-balance loss coefficient


@dataclass(frozen=True)
class LBGMConfig:
    """Paper Algorithm 1 knobs — arch-side view of ``fed.flconfig.FLConfig``.

    The algorithmic defaults (threshold, sampling, enablement) are FLConfig's
    own; only the pod-scale execution defaults (``num_clients`` per
    ("pod","data") axes, single local step) differ for the big-model
    training path. Convert with :meth:`to_fl` / ``FLConfig.from_lbgm``.
    """
    enabled: bool = _FL_DEFAULTS["use_lbgm"]
    variant: str = "full"           # "full" | "topk" (compressed LBG, paper P3)
    delta_threshold: float = _FL_DEFAULTS["delta_threshold"]
    k_frac: float = 0.01            # for variant="topk": fraction of entries kept
    num_clients: int = 16           # client groups along the ("pod","data") axes
    local_steps: int = 1            # tau; >1 only supported in replicated mode
    sample_frac: float = _FL_DEFAULTS["sample_frac"]

    def to_fl(self, **overrides) -> FLConfig:
        """The canonical engine config carrying these knobs."""
        return FLConfig.from_lbgm(self, **overrides)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | audio | vlm
    source: str                     # citation bracket from the assignment
    n_layers: int = 2
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    d_ff: int = 2048
    vocab_size: int = 32768
    head_dim: int = 0               # 0 => d_model // n_heads
    moe: MoEConfig = field(default_factory=MoEConfig)
    # block pattern: tuple cycled over layers. entries:
    #   "attn" (global), "swa" (sliding-window attn), "rwkv6", "rglru"
    block_pattern: Tuple[str, ...] = ("attn",)
    sliding_window: int = 8192      # used by "swa" blocks / long-context decode
    qk_norm: bool = False
    mrope: bool = False             # qwen2-vl multimodal rotary
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    encdec: bool = False            # whisper-style encoder-decoder
    n_encoder_layers: int = 0
    encoder_seq: int = 1500         # whisper stub frame count
    vision_tokens: int = 0          # qwen2-vl stub patch count (prepended)
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # distribution
    dp_mode: str = "replicated"     # "replicated" | "fsdp"
    remat: bool = True              # activation checkpointing per block
    lbgm: LBGMConfig = field(default_factory=LBGMConfig)
    # long-context decode policy: "swa" | "recurrent" | "skip" | "full"
    long_context: str = "swa"
    # unroll every lax.scan (layers, attention chunks, CE chunks, rwkv
    # chunk loop) — used by the dry-run cost pass because XLA cost analysis
    # counts while-loop bodies ONCE, not x trip count. Never for real runs.
    unroll: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def reduced(self, **overrides) -> "ArchConfig":
        """Reduced variant of the same family for CPU smoke tests."""
        small = dict(
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 32),
            encoder_seq=16 if self.encdec else self.encoder_seq,
            n_encoder_layers=2 if self.encdec else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            dp_mode="replicated",
            remat=False,
            dtype="float32",
            mrope_sections=(4, 6, 6) if self.mrope else self.mrope_sections,
            lbgm=dataclasses.replace(self.lbgm, num_clients=4),
        )
        if self.moe.num_experts:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4))
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (embeddings + blocks + head)."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd = cfg.resolved_head_dim
    n_q, n_kv = cfg.n_heads, cfg.n_kv_heads
    total = V * d                       # embed
    if not cfg.tie_embeddings:
        total += V * d                  # lm head
    for layer in range(cfg.n_layers):
        kind = cfg.block_kind(layer)
        if kind in ("attn", "swa"):
            total += d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
        elif kind == "rwkv6":
            # r,k,v,g,o projections + decay lora + mixing params
            total += 5 * d * d + 2 * d * 64 + 6 * d
        elif kind == "rglru":
            # conv4 + input/gate projections + recurrent params
            total += 4 * d + 2 * d * d + 3 * d
        if cfg.moe.num_experts and kind in ("attn", "swa"):
            total += cfg.moe.num_experts * 3 * d * ff + d * cfg.moe.num_experts
        else:
            total += 3 * d * ff
        total += 2 * d                  # norms
    if cfg.encdec:
        # encoder layers: self attn + ffn
        total += cfg.n_encoder_layers * (
            d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d + 3 * d * ff + 2 * d)
        # decoder cross-attention
        total += cfg.n_layers * (d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d + d)
    return total


def active_param_count(cfg: ArchConfig) -> int:
    """Params active per token (MoE: only top_k experts count)."""
    if not cfg.moe.num_experts:
        return param_count(cfg)
    dense = param_count(cfg)
    d, ff = cfg.d_model, cfg.d_ff
    moe_layers = sum(1 for l in range(cfg.n_layers)
                     if cfg.block_kind(l) in ("attn", "swa"))
    inactive = moe_layers * (cfg.moe.num_experts - cfg.moe.top_k) * 3 * d * ff
    return dense - inactive
