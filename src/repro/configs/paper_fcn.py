"""Paper-native FCN (S2 in the paper's experiments).

2-layer fully-connected classifier as used by the paper on MNIST/FMNIST.
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="paper-fcn",
    arch_type="fcn",
    source="ICLR2022 LBGM paper, setting S2",
    n_layers=2,
    d_model=128,          # hidden width
    vocab_size=10,        # classes
    dp_mode="replicated",
    dtype="float32",
    remat=False,
    lbgm=LBGMConfig(variant="full", delta_threshold=0.2,
                    num_clients=100, local_steps=2),
)
