"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
Dense per-client LBG is infeasible at this scale (DESIGN.md §3) => topk LBG.
"""
from repro.configs.base import ArchConfig, LBGMConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(num_experts=128, top_k=1, capacity_factor=1.25),
    block_pattern=("attn",),
    sliding_window=8192,
    dp_mode="fsdp",
    lbgm=LBGMConfig(variant="topk", k_frac=0.005, num_clients=16),
    long_context="swa",
)
