"""mistral-large-123b [dense]. [hf:mistralai/Mistral-Large-Instruct-2407]

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    arch_type="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    block_pattern=("attn",),
    sliding_window=8192,
    dp_mode="fsdp",
    lbgm=LBGMConfig(variant="topk", k_frac=0.01, num_clients=16),
    long_context="swa",
)
