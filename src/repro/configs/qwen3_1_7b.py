"""qwen3-1.7b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B]

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
Small enough for paper-faithful dense per-client LBGs.
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    block_pattern=("attn",),
    sliding_window=8192,
    dp_mode="replicated",
    lbgm=LBGMConfig(variant="full", num_clients=16),
    long_context="swa",
)
