"""deepseek-67b [dense] — llama-arch. [arXiv:2401.02954]

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    arch_type="dense",
    source="arXiv:2401.02954",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    block_pattern=("attn",),
    sliding_window=8192,
    dp_mode="fsdp",
    lbgm=LBGMConfig(variant="topk", k_frac=0.01, num_clients=16),
    long_context="swa",
)
