"""mixtral-8x22b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088]

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
"""
from repro.configs.base import ArchConfig, LBGMConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    source="arXiv:2401.04088",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    block_pattern=("swa",),
    sliding_window=4096,
    dp_mode="fsdp",
    lbgm=LBGMConfig(variant="topk", k_frac=0.01, num_clients=16),
    long_context="swa",
)
