"""whisper-base [audio] — enc-dec, conv frontend (STUB). [arXiv:2212.04356]

6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865.
The mel-spectrogram + conv feature extractor is a stub: input_specs()
provides precomputed (B, 1500, 512) frame embeddings (DESIGN.md carve-out).
long_500k is SKIPPED: decoder context architecturally capped (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="whisper-base",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=6,                 # decoder layers
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    encdec=True,
    encoder_seq=1500,
    block_pattern=("attn",),
    dp_mode="replicated",
    lbgm=LBGMConfig(variant="full", num_clients=16),
    long_context="skip",
)
