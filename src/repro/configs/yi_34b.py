"""yi-34b [dense] — llama-arch GQA. [arXiv:2403.04652]

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="yi-34b",
    arch_type="dense",
    source="arXiv:2403.04652",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    block_pattern=("attn",),
    sliding_window=8192,
    dp_mode="fsdp",
    lbgm=LBGMConfig(variant="topk", k_frac=0.01, num_clients=16),
    long_context="swa",
)
