"""Architecture registry: ``--arch <id>`` resolves through REGISTRY."""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, LBGMConfig, MoEConfig, ShapeConfig,
                                INPUT_SHAPES, param_count, active_param_count)

_MODULES = {
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "whisper-base": "repro.configs.whisper_base",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "yi-34b": "repro.configs.yi_34b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "paper-cnn": "repro.configs.paper_cnn",
    "paper-fcn": "repro.configs.paper_fcn",
}

ASSIGNED_ARCHS = [k for k in _MODULES if not k.startswith("paper-")]


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs():
    return {name: get_config(name) for name in _MODULES}


__all__ = [
    "ArchConfig", "LBGMConfig", "MoEConfig", "ShapeConfig", "INPUT_SHAPES",
    "param_count", "active_param_count", "get_config", "all_configs",
    "ASSIGNED_ARCHS",
]
