"""Paper-native CNN (S1 in the paper's experiments, Figs. 5-8).

A 4-layer conv classifier as used by the paper on MNIST/FMNIST/CIFAR-10.
Used for paper-faithful FL validation on synthetic image-like data.
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="paper-cnn",
    arch_type="cnn",
    source="ICLR2022 LBGM paper, setting S1",
    n_layers=4,
    d_model=32,           # base channel width
    vocab_size=10,        # classes
    dp_mode="replicated",
    dtype="float32",
    remat=False,
    lbgm=LBGMConfig(variant="full", delta_threshold=0.2,
                    num_clients=100, local_steps=2),
)
