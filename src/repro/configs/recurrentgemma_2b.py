"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 2:1. [arXiv:2402.19427]

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
Block pattern cycles (rglru, rglru, swa); local attention window 2048.
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    source="arXiv:2402.19427",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "swa"),
    sliding_window=2048,
    dp_mode="replicated",
    lbgm=LBGMConfig(variant="full", num_clients=16),
    long_context="recurrent",
)
