"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191]

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
Vision encoder (ViT) is a STUB: input_specs() provides patch embeddings
(B, vision_tokens, d_model) consumed by the language backbone.
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    source="arXiv:2409.12191",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    mrope_sections=(16, 24, 24),   # temporal/h/w sections summing to head_dim/2
    vision_tokens=256,
    block_pattern=("attn",),
    sliding_window=8192,
    dp_mode="replicated",
    lbgm=LBGMConfig(variant="full", num_clients=16),
    long_context="swa",
)
