"""rwkv6-3b [ssm] — Finch, data-dependent decay. [arXiv:2404.05892]

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
LBGM applies unchanged (gradient-space technique, model-agnostic).
"""
from repro.configs.base import ArchConfig, LBGMConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    source="arXiv:2404.05892",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # rwkv6 heads = d_model / 64
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv6",),
    dp_mode="replicated",
    lbgm=LBGMConfig(variant="full", num_clients=16),
    long_context="recurrent",
)
