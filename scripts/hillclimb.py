import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb on the three selected (arch x shape) pairs.

Picks (from the baseline roofline table):
  1. qwen3-1.7b x train_4k        — most representative of the paper
     (replicated DP, dense per-client LBGM, Algorithm 1 byte-for-byte);
     collective-dominated.
  2. llama4-maverick x train_4k   — most collective-bound pair in the
     whole table (FSDP parameter re-gathers x clients).
  3. rwkv6-3b x train_4k          — worst collective:compute ratio among
     replicated archs (attention-free SSM; biggest all-gather waste).

Each experiment: hypothesis -> config/sharding change -> re-lower ->
re-measure the roofline terms. Results land in experiments/hillclimb/.
"""
import dataclasses     # noqa: E402
import json            # noqa: E402

import jax.numpy as jnp                      # noqa: E402
from repro.configs import get_config         # noqa: E402
from repro.launch.dryrun import lower_pair   # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

OUT = "experiments/hillclimb"


def run(tag, arch, shape, mesh, **kw):
    print(f"--- {tag}", flush=True)
    row = lower_pair(arch, shape, mesh, "pod16x16", **kw)
    row["experiment"] = tag
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"{tag}.json"), "w") as f:
        json.dump(row, f, indent=1, default=str)
    if row["status"] == "ok":
        print(f"    terms=({row['compute_s']:.4f}, {row['memory_s']:.4f}, "
              f"{row['collective_s']:.4f})s dominant={row['dominant']} "
              f"coll_GiB={row['coll_bytes_per_dev']/2**30:.2f}", flush=True)
    else:
        print("    ", row.get("error", row["status"])[-500:], flush=True)
    return row


def main():
    mesh = make_production_mesh()
    unroll = {}  # scan mode: the attacked collectives are outside the layer scan

    # ---------------- pick 1: qwen3-1.7b train_4k (paper-representative)
    a, s = "qwen3-1.7b", "train_4k"
    run("qwen3_base", a, s, mesh, **unroll)                       # baseline
    # H1: the stacked per-client gradient mean over the K-sharded axis is
    # lowered as all-gather(K x M/16) instead of partial-sum+all-reduce;
    # and the vocab-sharded embedding table is all-gathered per client.
    # Change A: shard the embedding along d_model => token gathers local.
    run("qwen3_embedshard", a, s, mesh, embed_shard="embed", **unroll)
    # Change B: aggregate the reconstructed gradients in bf16 (halves the
    # payload of whatever collective implements the client reduction).
    run("qwen3_bf16agg", a, s, mesh, agg_dtype=jnp.bfloat16, **unroll)
    # Change C: both.
    run("qwen3_embed_bf16", a, s, mesh, embed_shard="embed",
        agg_dtype=jnp.bfloat16, **unroll)

    # ---------------- pick 2: llama4 train_4k (most collective-bound)
    a = "llama4-maverick-400b-a17b"
    base_cfg = get_config(a)
    # baseline at true K=16 (scan body counts one client; x16 in analysis)
    run("llama4_base_K16", a, s, mesh, clients_override=16)
    # H2a: remat re-gathers FSDP weights in the backward => ~2x all-gather.
    run("llama4_noremat_K16", a, s, mesh, clients_override=16,
        cfg_override=dataclasses.replace(base_cfg, remat=False))
    # H2b: fewer, larger clients: all-gather traffic scales with K.
    run("llama4_K4", a, s, mesh, clients_override=4)
    # H2c: combined.
    run("llama4_noremat_K4", a, s, mesh, clients_override=4,
        cfg_override=dataclasses.replace(base_cfg, remat=False))

    # ---------------- pick 3: rwkv6-3b train_4k (worst coll ratio, SSM)
    a = "rwkv6-3b"
    run("rwkv6_base", a, s, mesh, **unroll)
    run("rwkv6_embedshard", a, s, mesh, embed_shard="embed", **unroll)
    run("rwkv6_bf16agg", a, s, mesh, agg_dtype=jnp.bfloat16, **unroll)
    run("rwkv6_embed_bf16", a, s, mesh, embed_shard="embed",
        agg_dtype=jnp.bfloat16, **unroll)


if __name__ == "__main__":
    main()
