"""Build the EXPERIMENTS.md roofline/dry-run tables from the JSON records."""
from __future__ import annotations

import glob
import json
import os
import sys

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ORDER_ARCHS = [
    "llama4-maverick-400b-a17b", "rwkv6-3b", "mistral-large-123b",
    "qwen3-1.7b", "whisper-base", "recurrentgemma-2b", "mixtral-8x22b",
    "qwen2-vl-2b", "yi-34b", "deepseek-67b",
]


def load(mesh_dir: str, suffix: str = ""):
    rows = {}
    for arch in ORDER_ARCHS:
        for shape in ORDER_SHAPES:
            path = os.path.join(mesh_dir, f"{arch}__{shape}{suffix}.json")
            if os.path.exists(path):
                rows[(arch, shape)] = json.load(open(path))
    return rows


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}u"


def table(rows, unroll_rows=None, caption=""):
    out = [caption,
           "| arch | shape | status | dominant | compute_s | memory_s | "
           "collective_s | useful | HBM/dev (GB) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ORDER_ARCHS:
        for shape in ORDER_SHAPES:
            r = rows.get((arch, shape))
            if r is None:
                out.append(f"| {arch} | {shape} | MISSING | | | | | | |")
                continue
            if r["status"] == "skipped":
                out.append(f"| {arch} | {shape} | skipped (enc-dec ctx cap) "
                           f"| | | | | | |")
                continue
            if r["status"] != "ok":
                out.append(f"| {arch} | {shape} | FAILED | | | | | | |")
                continue
            u = (unroll_rows or {}).get((arch, shape))
            src = u if (u and u.get("status") == "ok") else r
            note = "" if src is u else "†"
            out.append(
                f"| {arch} | {shape} | ok | {src['dominant']}{note} | "
                f"{fmt_s(src['compute_s'])} | {fmt_s(src['memory_s'])} | "
                f"{fmt_s(src['collective_s'])} | "
                f"{src['useful_flops_ratio']:.2f} | "
                f"{r.get('hbm_per_device_gb', 0):.1f} |")
    return "\n".join(out)


def main():
    base = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    single = load(os.path.join(base, "pod16x16"))
    single_unroll = load(os.path.join(base, "pod16x16"), "__unroll")
    multi = load(os.path.join(base, "pod2x16x16"))
    print("### Single-pod (16x16 = 256 chips): roofline terms "
          "(unrolled cost pass; † = scan-counted fallback)\n")
    print(table(single, single_unroll))
    print("\n### Multi-pod (2x16x16 = 512 chips): lowering/compile proof\n")
    print(table(multi))
    n_ok = sum(1 for r in list(single.values()) if r["status"] == "ok")
    n_skip = sum(1 for r in list(single.values())
                 if r["status"] == "skipped")
    m_ok = sum(1 for r in list(multi.values()) if r["status"] == "ok")
    print(f"\nsingle-pod: {n_ok} ok / {n_skip} documented skips of "
          f"{len(single)}; multi-pod: {m_ok} ok of {len(multi)}")


if __name__ == "__main__":
    main()
