"""FL runtime end-to-end: learning, savings, sampling, plug-and-play, and the
delta->0 equivalence with vanilla FL (paper takeaway 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_iid, partition_label_skew
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-fcn")
    key = jax.random.PRNGKey(0)
    params, _ = init_fcn(key, cfg)
    x, y = mixture_classification(1200, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    return cfg, params, x, y, loss_fn


def _make(setup, parts_fn, **flkw):
    cfg, params, x, y, loss_fn = setup
    K = flkw.pop("num_clients", 10)
    parts = parts_fn(y, K)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    fl = FLEngine(loss_fn, params, data,
                  FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                           **flkw))
    return fl


def _skew(y, k):
    return partition_label_skew(y, k, 3, seed=0)


def _iid(y, k):
    return partition_iid(len(y), k, seed=0)


def test_lbgm_learns_and_saves(setup):
    fl = _make(setup, _skew, use_lbgm=True, delta_threshold=0.2)
    hist = fl.run(15)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7
    assert hist[-1]["savings"] > 0.1
    assert 0.0 < hist[-1]["frac_scalar"] <= 1.0


def test_delta_zero_equals_vanilla(setup):
    """delta=0 forces full rounds every time => identical trajectory to
    vanilla FL (paper takeaway 1: recovering the vanilla-FL bound)."""
    fl_lbgm = _make(setup, _iid, use_lbgm=True, delta_threshold=-1.0)
    fl_van = _make(setup, _iid, use_lbgm=False)
    h1 = fl_lbgm.run(4)
    h2 = fl_van.run(4)
    for k in fl_lbgm.params:
        np.testing.assert_allclose(np.asarray(fl_lbgm.params[k]),
                                   np.asarray(fl_van.params[k]),
                                   rtol=1e-5, atol=1e-6)
    assert all(h["frac_scalar"] == 0.0 for h in h1)


def test_client_sampling(setup):
    fl = _make(setup, _skew, use_lbgm=True, sample_frac=0.5)
    hist = fl.run(10)
    assert hist[-1]["loss"] < hist[0]["loss"]
    # vanilla baseline accounting counts only sampled clients
    assert fl.vanilla_uplink < 10 * 10 * 1e9


@pytest.mark.parametrize("compressor,kw", [
    ("topk", {"k_frac": 0.1}),
    ("signsgd", {}),
    ("atomo", {"rank": 2}),
])
def test_plug_and_play(setup, compressor, kw):
    """LBGM stacked on top-K / ATOMO / SignSGD (paper P3/P4)."""
    fl = _make(setup, _iid, use_lbgm=True, delta_threshold=0.3,
               compressor=compressor, compressor_kw=kw)
    hist = fl.run(8)
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["loss"] < hist[0]["loss"] * 1.05
    base = _make(setup, _iid, use_lbgm=False, compressor=compressor,
                 compressor_kw=kw)
    bh = base.run(8)
    # LBGM adds savings on top of the base compressor
    assert fl.total_uplink <= base.total_uplink


def test_noniid_partition_properties():
    _, y = mixture_classification(500, 10, seed=1)
    parts = partition_label_skew(y, 8, 3, seed=0)
    assert len(parts) == 8
    for p in parts:
        assert len(set(y[p])) <= 3 and len(p) > 0


@pytest.mark.parametrize("num_clients,seed", [(8, 0), (30, 1), (100, 2)])
def test_label_skew_shards_disjoint_and_covering(num_clients, seed):
    """Regression (ISSUE 3): the old `per` formula + wraparound pointer
    handed the same samples to multiple clients and left others unassigned.
    Client shards must be pairwise disjoint, and every class somebody drew
    must be fully dealt out across its takers."""
    _, y = mixture_classification(1500, 10, seed=3)
    parts = partition_label_skew(y, num_clients, 3, seed=seed)
    allidx = np.concatenate(parts)
    # pairwise disjoint: no index appears in two client shards
    assert len(allidx) == len(np.unique(allidx))
    # full coverage: every sample of every drawn class is assigned
    drawn_classes = set()
    for p in parts:
        drawn_classes.update(np.unique(y[p]).tolist())
    assigned = np.zeros(len(y), bool)
    assigned[allidx] = True
    for c in drawn_classes:
        assert assigned[y == c].all(), f"class {c} not fully dealt out"
    # cohort demand <= supply here (150 samples/class): nobody is empty
    for p in parts:
        assert len(p) > 0 and len(set(y[p])) <= 3
