"""Sharded-LBGM (shard_map variant): semantic equivalence with the pjit
top-k step on a real multi-device mesh (subprocess, 8 host devices)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import lbgm as L
from repro.core import lbgm_sharded as LS

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
# two leaves: one sharded over both axes, one over model only
g = {"a": jax.random.normal(key, (8, 16)),
     "b": jax.random.normal(jax.random.fold_in(key, 1), (12,))}
gspecs = {"a": P("data", "model"), "b": P(None)}
k_frac = 0.25
delta = 0.9

with mesh:
    gs = {k: jax.device_put(v, NamedSharding(mesh, gspecs[k]))
          for k, v in g.items()}
    lbg = LS.init_sharded_lbg(g, gspecs, mesh, k_frac)
    step = jax.jit(LS.make_sharded_topk_step(
        type("C", (), {"lbgm": type("L2", (), {"k_frac": k_frac})})(),
        mesh, gspecs, delta))
    # round 1: zero LBG => full round
    gt1, lbg1, s1 = step(gs, lbg)
    assert not bool(s1.sent_scalar), float(s1.sin2)
    # g_tilde is blockwise-topk(g): nonzeros of gt1 must equal g there
    for kname in g:
        d = np.asarray(gt1[kname])
        nz = d != 0
        np.testing.assert_allclose(d[nz], np.asarray(g[kname])[nz],
                                   rtol=1e-5)
    # round 2: scaled gradient => scalar round, reconstruction rho*lbg
    gs2 = jax.tree.map(lambda x: 3.0 * x, gs)
    gt2, lbg2, s2 = step(gs2, lbg1)
    assert bool(s2.sent_scalar), float(s2.sin2)
    np.testing.assert_allclose(float(s2.rho), 3.0, rtol=1e-3)
    for kname in g:
        np.testing.assert_allclose(np.asarray(gt2[kname]),
                                   3.0 * np.asarray(gt1[kname]), rtol=1e-3,
                                   atol=1e-5)
    # stats must agree with the dense-global computation
    gg_ref = sum(float(jnp.sum(v.astype(jnp.float32) ** 2))
                 for v in g.values())
    np.testing.assert_allclose(float(s1.grad_sq_norm), gg_ref, rtol=1e-4)
print(json.dumps({"ok": True}))
"""


@pytest.mark.slow
def test_sharded_lbgm_equivalence():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
