"""Wire codec subsystem tests (repro.comm.wire + engine integration).

Five pillars:
  (a) codec primitives against pure-NumPy oracles: stochastic-rounding
      unbiasedness, int8/fp8 nearest-rounding error bounds, exact
      requantization idempotency (the power-of-two-scale property the
      deployment-faithfulness argument rests on), and the varint-delta
      index byte count (incl. degenerate kb=1 rows and pad rows),
  (b) ``codec="none"`` is the pre-codec engine bit-for-bit: round
      histories on all three schedulers match the golden fixture captured
      at the pre-codec revision (tests/golden/engine_history_pre_codec
      .json), and ``delta_idx`` only changes the byte metric,
  (c) the fused dequant-accumulate kernel (interpret mode) is
      bit-identical to its XLA oracle, standalone and through full engine
      histories,
  (d) quantized engine runs: scheduler equivalence, wire-byte math vs
      hand-computed oracles, the >= 3x int8-over-fp32-LBGM byte-reduction
      contract at matched accuracy, CommLedger bookkeeping, and the
      actionable config errors (lossy + dense bank, scalar_median without
      the sparse path, unknown codec, bad codec_kw),
  (e) the ``scalar_median`` O(K) robust rule: weighted-median oracle and
      agreement with the geometric median on rank-1 payload stacks.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.accounting import CommLedger
from repro.comm.wire import (E4M3_MAX, WIRE_KEY, Fp8Codec, Int8Codec,
                             codec_rng, delta_idx_bytes, e4m3_nearest,
                             make_codec, pow2_scale, stochastic_round)
from repro.fed import FLConfig, FLEngine
from repro.fed.registry import CODECS
from repro.fed.robust import GeometricMedian, ScalarMedian, \
    ScalarMedianSparseAggregator
from repro.kernels.ops import lbgm_dequant_accum
from repro.kernels.ref import lbgm_dequant_accum_ref

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "engine_history_pre_codec.json")

# --------------------------------------------------------------- fixtures


@pytest.fixture(scope="module")
def fcn_setup():
    from repro.configs import get_config
    from repro.data.synthetic import mixture_classification
    from repro.models.smallnets import (apply_fcn, classifier_loss,
                                        init_fcn)
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(1200, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg,
                                           b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=6, **flkw):
    from repro.fed import partition_label_skew
    params, x, y, loss_fn = fcn_setup
    parts = partition_label_skew(y, K, 3, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             **flkw))


def run_rounds(fl, n=3, seed=0):
    rng = np.random.RandomState(seed)
    return [fl.run_round(rng) for _ in range(n)]


#: the exact FLConfig kwargs the golden fixture was generated with
GOLDEN_BASE = dict(use_lbgm=True, delta_threshold=0.2, sample_frac=0.7)
GOLDEN_SCHED = {
    "vmap": dict(scheduler="vmap"),
    "chunked": dict(scheduler="chunked", chunk_size=4),
    "sharded": dict(scheduler="sharded", chunk_size=4,
                    lbg_variant="topk-sharded", lbg_kw={"k_frac": 0.25}),
}

#: sparse top-k payload configs (the quantized codecs' home turf)
TOPK_SCHED = {
    "vmap": dict(scheduler="vmap", lbg_variant="topk",
                 lbg_kw={"k_frac": 0.25}),
    "chunked": dict(scheduler="chunked", chunk_size=4, lbg_variant="topk",
                    lbg_kw={"k_frac": 0.25}),
    "sharded": dict(scheduler="sharded", chunk_size=4,
                    lbg_variant="topk-sharded", lbg_kw={"k_frac": 0.25}),
}


# ------------------------------------------- (a) primitive vs NumPy oracle


def test_stochastic_round_unbiased_and_integer_fixed():
    rng = np.random.RandomState(0)
    f = jnp.asarray(rng.randn(64).astype(np.float32) * 7)
    u = jnp.asarray(rng.rand(4000, 64).astype(np.float32))
    q = stochastic_round(f, u)                     # broadcast over draws
    assert np.array_equal(np.asarray(q), np.floor(np.asarray(q)))
    frac = np.asarray(f) - np.floor(np.asarray(f))
    sigma = np.sqrt(np.maximum(frac * (1 - frac), 1e-12) / 4000)
    np.testing.assert_array_less(
        np.abs(np.asarray(q.mean(0)) - np.asarray(f)), 5 * sigma + 1e-6)
    # exact integers are fixed points for EVERY draw
    ints = jnp.asarray(np.arange(-5, 6, dtype=np.float32))
    np.testing.assert_array_equal(
        np.asarray(stochastic_round(ints, u[:, :11])),
        np.broadcast_to(np.asarray(ints), (4000, 11)))


def test_pow2_scale_oracle():
    m = jnp.asarray([0.0, 1e-9, 0.5, 127.0, 128.0, 1e4], jnp.float32)
    s = np.asarray(pow2_scale(m, 127.0))
    for mi, si in zip(np.asarray(m), s):
        if mi == 0:
            assert si == 1.0
        else:
            assert si == 2.0 ** np.ceil(np.log2(mi / 127.0))
            assert mi / si <= 127.0 and mi / (si / 2) > 127.0 * (1 - 1e-6)


@pytest.mark.parametrize("codec_cls,max_rel", [(Int8Codec, 1.0 / 127.0),
                                               (Fp8Codec, 1.0 / 16.0)])
def test_nearest_quantization_error_bound(codec_cls, max_rel):
    """Nearest rounding: per-row error <= half the worst grid step, i.e.
    int8: scale/2 <= rowmax/127; fp8 e4m3: rel error <= 2^-4 in-binade."""
    rng = np.random.RandomState(1)
    val = jnp.asarray(rng.randn(16, 128).astype(np.float32) * 3)
    codec = codec_cls(stochastic=False)
    q, scale = codec.quantize(val, None)
    dq = np.asarray(codec.decode_leaf(
        {"idx": None, "val": q, "scale": scale}))
    rowmax = np.max(np.abs(np.asarray(val)), axis=-1, keepdims=True)
    assert np.all(np.abs(dq - np.asarray(val)) <= rowmax * max_rel + 1e-7)


@pytest.mark.parametrize("codec_cls", [Int8Codec, Fp8Codec])
@pytest.mark.parametrize("stochastic", [True, False])
def test_requantization_idempotent(codec_cls, stochastic):
    """dequant(quant(v)) is a fixed point of quant-dequant — exactly.

    This is the deployment-faithfulness property: the bank holds grid
    values, and re-encoding them every round (as the payload path does)
    must reproduce them bit-for-bit under ANY rounding seed."""
    rng = np.random.RandomState(2)
    val = jnp.asarray(rng.randn(8, 64).astype(np.float32))
    codec = codec_cls(stochastic=stochastic)
    key = jax.random.PRNGKey(0) if stochastic else None
    q, scale = codec.quantize(val, key)
    v1 = codec.decode_leaf({"idx": None, "val": q, "scale": scale})
    for seed in (1, 2, 3):
        key2 = jax.random.PRNGKey(seed) if stochastic else None
        q2, scale2 = codec.quantize(v1, key2)
        v2 = codec.decode_leaf({"idx": None, "val": q2, "scale": scale2})
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_e4m3_nearest_saturates_and_hits_grid():
    x = jnp.asarray([0.0, 1.0, 447.0, 449.0, 1e6, -1e6, 0.3], jnp.float32)
    out = np.asarray(e4m3_nearest(x))
    assert out[3] == E4M3_MAX and out[4] == E4M3_MAX
    assert out[5] == -E4M3_MAX
    # grid values survive a second pass exactly
    np.testing.assert_array_equal(out, np.asarray(e4m3_nearest(out)))


def np_varint_bytes(idx):
    """Hand-computed varint-delta byte count (the wire-format oracle)."""
    total = 0
    for row in np.asarray(idx).reshape(-1, idx.shape[-1]):
        prev = 0
        for v in np.sort(row):
            d = int(v) - prev
            total += 1 if d < (1 << 7) else (2 if d < (1 << 14) else 3)
            prev = int(v)
    return float(total)


@pytest.mark.parametrize("shape,high", [((6, 17), 1 << 15), ((4, 1), 9000),
                                        ((1, 64), 200), ((3, 5), 1 << 16)])
def test_delta_idx_bytes_matches_numpy_oracle(shape, high):
    rng = np.random.RandomState(3)
    idx = rng.randint(0, high, size=shape).astype(np.int32)
    got = float(delta_idx_bytes(jnp.asarray(idx)))
    assert got == np_varint_bytes(idx)


def test_delta_idx_bytes_degenerate_and_pad_rows():
    # kb = 1: exactly one varint per row (the first index, delta from 0)
    one = jnp.asarray([[5], [200], [40000]], jnp.int32)
    assert float(delta_idx_bytes(one)) == 1 + 2 + 3
    # pad rows (iota indices, the phantom-client payload): all deltas are
    # 1 -> 1 byte each, same as the NumPy oracle prices them
    pad = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32), (4, 32))
    assert float(delta_idx_bytes(pad)) == np_varint_bytes(np.asarray(pad))
    assert float(delta_idx_bytes(pad)) == 4 * 32


def test_codec_registry_and_kw_errors():
    assert set(CODECS.names()) >= {"none", "delta_idx", "int8", "fp8"}
    cfg = FLConfig(num_clients=2, codec="int8",
                   codec_kw={"stochastic": False})
    codec = make_codec(cfg)
    assert codec.lossy and not codec.stochastic
    with pytest.raises(ValueError, match="zstd"):
        FLConfig(num_clients=2, codec="zstd")
    with pytest.raises(ValueError, match="codec_kw"):
        make_codec(FLConfig(num_clients=2, codec="int8",
                            codec_kw={"bogus": 1}))
    # JSON round-trip carries the codec knobs
    cfg2 = FLConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert cfg2.codec == "int8" and cfg2.codec_kw == {"stochastic": False}


def test_codec_rng_dedicated_stream():
    a, b = codec_rng(0), codec_rng(0)
    assert np.array_equal(a.randint(0, 2 ** 31 - 1, 8),
                          b.randint(0, 2 ** 31 - 1, 8))
    assert not np.array_equal(codec_rng(0).randint(0, 2 ** 31 - 1, 8),
                              codec_rng(1).randint(0, 2 ** 31 - 1, 8))


def test_commledger_byte_math_oracle():
    led = CommLedger()
    led.record(10.0, 100.0, wire=40.0, vanilla_wire=400.0)
    led.record(1.0, 100.0, wire=1.0, vanilla_wire=400.0)
    assert led.rounds == 2
    assert led.uplink_floats == 11.0 and led.vanilla_floats == 200.0
    assert led.wire_bytes == 41.0 and led.vanilla_wire_bytes == 800.0
    assert led.savings == 1.0 - 11.0 / 200.0
    assert led.wire_savings == 1.0 - 41.0 / 800.0
    assert led.per_round[1] == {"uplink": 1.0, "vanilla": 100.0,
                                "wire": 1.0, "vanilla_wire": 400.0}
    s = led.summary()
    assert s["wire_bytes"] == 41.0 and s["wire_savings"] == led.wire_savings
    assert CommLedger().wire_savings == 0.0


# ------------------------- (b) codec="none" bit-for-bit vs golden fixture


@pytest.mark.parametrize("sched", sorted(GOLDEN_SCHED))
def test_codec_none_bit_for_bit_with_pre_codec_history(fcn_setup, sched):
    """The default codec reproduces the round histories captured at the
    revision BEFORE the codec subsystem existed, float-exact, on all
    three schedulers (the fixture stores float.hex strings)."""
    with open(GOLDEN) as f:
        golden = json.load(f)[sched]
    fl = make_engine(fcn_setup, **GOLDEN_BASE, **GOLDEN_SCHED[sched])
    hist = run_rounds(fl, n=len(golden))
    for r, (h, gh) in enumerate(zip(hist, golden)):
        for k, v in gh.items():
            assert float.fromhex(v) == h[k], (sched, r, k)


def test_delta_idx_only_changes_byte_metric(fcn_setup):
    """Lossless index compression: every pre-existing history number is
    bit-equal to codec='none'; only wire_bytes shrinks (equal on a pure
    scalar round, where neither codec ships indices)."""
    kw = TOPK_SCHED["chunked"]
    h0 = run_rounds(make_engine(fcn_setup, **GOLDEN_BASE, **kw))
    h1 = run_rounds(make_engine(fcn_setup, codec="delta_idx",
                                **GOLDEN_BASE, **kw))
    for a, b in zip(h0, h1):
        for k in ("loss", "uplink_floats", "frac_scalar", "total_uplink",
                  "vanilla_uplink", "savings"):
            assert a[k] == b[k], k
        assert b["wire_bytes"] <= a["wire_bytes"]
    assert h1[-1]["total_wire_bytes"] < h0[-1]["total_wire_bytes"]


# --------------------------------- (c) fused dequant-accumulate vs oracle


@pytest.mark.parametrize("wire_dtype", ["int8", "fp8"])
@pytest.mark.parametrize("seed", range(3))
def test_dequant_accum_kernel_matches_ref(wire_dtype, seed):
    rng = np.random.RandomState(seed)
    C, nb, kb, block = 5, 4, 8, 32
    acc = jnp.asarray(rng.randn(nb, block).astype(np.float32))
    w = jnp.asarray(rng.rand(C).astype(np.float32))
    w = w.at[seed % C].set(0.0)                 # a phantom client
    gscale = jnp.asarray(rng.rand(C).astype(np.float32))
    # phantom payloads may be NaN — the w > 0 gate must keep them out
    gscale = gscale.at[seed % C].set(np.nan)
    idx = jnp.asarray(
        np.stack([np.stack([rng.choice(block, kb, replace=False)
                            for _ in range(nb)]) for _ in range(C)])
        .astype(np.int32))
    val = rng.randn(C, nb, kb).astype(np.float32)
    val[seed % C] = np.nan
    codec = (Int8Codec if wire_dtype == "int8" else Fp8Codec)(
        stochastic=False)
    qv, scale = jax.vmap(lambda v: codec.quantize(v, None))(
        jnp.asarray(np.nan_to_num(val)))
    if wire_dtype == "fp8":
        qv = qv.at[seed % C].set(jnp.nan)       # NaN survives e4m3
    ref = lbgm_dequant_accum_ref(acc, w, gscale, idx, qv, scale)
    out = lbgm_dequant_accum(acc, w, gscale, idx, qv, scale,
                             interpret=True)
    assert np.all(np.isfinite(np.asarray(ref)))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_engine_fused_dequant_accum_bit_equals_xla(fcn_setup):
    """fused_kernels=True (interpret-mode Pallas dequant-accumulate) vs
    the default XLA fallback: identical int8 histories."""
    kw = dict(codec="int8", **GOLDEN_BASE, **TOPK_SCHED["vmap"])
    h_ref = run_rounds(make_engine(fcn_setup, **kw))
    h_fused = run_rounds(make_engine(fcn_setup, fused_kernels=True, **kw))
    for a, b in zip(h_ref, h_fused):
        assert a == b


# ----------------------------------------- (d) quantized engine contracts


@pytest.mark.parametrize("codec", ["int8", "fp8"])
def test_quantized_schedulers_agree(fcn_setup, codec):
    """vmap and chunked (same topk store, pure layout change) produce
    bit-identical quantized histories — the codec seam composes with the
    execution layout; the sharded/topk-sharded path (a different bank
    layout, so a different but valid trajectory) converges too."""
    h_v = run_rounds(make_engine(fcn_setup, codec=codec, **GOLDEN_BASE,
                                 **TOPK_SCHED["vmap"]))
    h_c = run_rounds(make_engine(fcn_setup, codec=codec, **GOLDEN_BASE,
                                 **TOPK_SCHED["chunked"]))
    assert h_v == h_c
    h_s = run_rounds(make_engine(fcn_setup, codec=codec, **GOLDEN_BASE,
                                 **TOPK_SCHED["sharded"]))
    assert all(np.isfinite(e["loss"]) for e in h_v + h_s)
    assert h_s[-1]["total_wire_bytes"] > 0


def test_vanilla_dense_int8_wire_byte_oracle(fcn_setup):
    """use_lbgm=False + int8: every participant ships M 1-byte values +
    one 4-byte scale per leaf; hand-computed bytes match exactly."""
    fl = make_engine(fcn_setup, codec="int8", use_lbgm=False,
                     sample_frac=1.0)
    h = run_rounds(fl, n=2)
    M = sum(int(p.size) for p in fl.params.values())
    L = len(fl.params)
    K = fl.cfg.num_clients
    for e in h:
        assert e["wire_bytes"] == K * (M + 4 * L)
    assert h[-1]["total_wire_bytes"] == 2 * K * (M + 4 * L)
    expect_savings = 1.0 - (M + 4 * L) / (4.0 * M)
    assert abs(h[-1]["wire_savings"] - expect_savings) < 1e-9


def test_sparse_none_full_round_wire_byte_oracle(fcn_setup):
    """codec='none' full rounds on the top-k store price the (fp32 value,
    raw int32 index) pair: 8 bytes per kept entry, padded block layout."""
    from repro.core.lbgm import _block_layout
    fl = make_engine(fcn_setup, **dict(GOLDEN_BASE, sample_frac=1.0),
                     **TOPK_SCHED["vmap"])
    h = run_rounds(fl, n=1)          # round 1 is a full round everywhere
    expect = 0.0
    for p in fl.params.values():
        nb, _, kb = _block_layout(int(p.size), 0.25)
        expect += 8.0 * kb * nb       # 4B fp32 value + 4B raw int32 index
    assert h[0]["frac_scalar"] == 0.0
    assert h[0]["wire_bytes"] == fl.cfg.num_clients * expect


def test_int8_beats_fp32_lbgm_by_3x(fcn_setup):
    """The PR's acceptance contract at test scale: int8 wire bytes are
    >= 3x smaller than fp32 LBGM wire bytes on the same run."""
    kw = dict(sample_frac=1.0, **{k: v for k, v in GOLDEN_BASE.items()
                                  if k != "sample_frac"})
    base = run_rounds(make_engine(fcn_setup, **kw, **TOPK_SCHED["chunked"]))
    q = run_rounds(make_engine(fcn_setup, codec="int8", **kw,
                               **TOPK_SCHED["chunked"]))
    ratio = base[-1]["total_wire_bytes"] / q[-1]["total_wire_bytes"]
    assert ratio >= 3.0, ratio
    assert abs(base[-1]["loss"] - q[-1]["loss"]) < 0.05


def test_scalar_round_wire_is_one_byte_quantized(fcn_setup):
    """Force recycle rounds (huge delta threshold after warmup): each
    participant's wire cost collapses to scalar_bytes (1 for int8)."""
    fl = make_engine(fcn_setup, codec="int8", use_lbgm=True,
                     delta_threshold=50.0, sample_frac=1.0,
                     **TOPK_SCHED["vmap"])
    h = run_rounds(fl, n=3)
    assert h[-1]["frac_scalar"] == 1.0
    assert h[-1]["wire_bytes"] == fl.cfg.num_clients * 1.0


def test_lossy_codec_requires_sparse_or_vanilla(fcn_setup):
    with pytest.raises(ValueError, match="lossy"):
        make_engine(fcn_setup, codec="int8", **GOLDEN_BASE)  # dense bank
    # lossless codec on the dense bank is fine
    make_engine(fcn_setup, codec="delta_idx", **GOLDEN_BASE)


def test_deterministic_codec_draws_no_seeds(fcn_setup):
    """codec_kw={'stochastic': False} must not put WIRE_KEY in the batch
    (rng-stream contract: deterministic codecs leave every stream
    untouched)."""
    fl = make_engine(fcn_setup, codec="int8",
                     codec_kw={"stochastic": False}, **GOLDEN_BASE,
                     **TOPK_SCHED["vmap"])
    batch = fl._sample_batches(np.random.RandomState(0))
    assert WIRE_KEY not in batch
    fl2 = make_engine(fcn_setup, codec="int8", **GOLDEN_BASE,
                      **TOPK_SCHED["vmap"])
    batch2 = fl2._sample_batches(np.random.RandomState(0))
    assert WIRE_KEY in batch2
    run_rounds(fl, n=2)              # and the deterministic path runs


def test_collect_sparse_decodes_quantized_payloads(fcn_setup):
    """Robust collect rules compose with a lossy codec (decode seam)."""
    h = run_rounds(make_engine(fcn_setup, codec="int8",
                               aggregator="geometric_median",
                               **GOLDEN_BASE, **TOPK_SCHED["chunked"]))
    assert all(np.isfinite(e["loss"]) for e in h)


# ----------------------------------------------------- (e) scalar_median


def np_weighted_median(w, gs):
    gs = np.where(w > 0, gs, 0.0).astype(np.float64)
    order = np.argsort(gs, kind="stable")
    v, ws = gs[order], w.astype(np.float64)[order]
    cum = np.cumsum(ws)
    return v[int(np.argmax(cum >= 0.5 * w.sum()))]


@pytest.mark.parametrize("seed", range(5))
def test_scalar_median_matches_numpy_oracle(seed):
    rng = np.random.RandomState(seed)
    K = 9
    w = rng.rand(K).astype(np.float32)
    w[seed % K] = 0.0
    w /= w.sum()
    gs = rng.randn(K).astype(np.float32) * 3
    gs[seed % K] = np.nan            # phantom client: masked by w > 0
    med = float(ScalarMedian().median(jnp.asarray(w), jnp.asarray(gs)))
    assert med == np.float32(np_weighted_median(w, gs))


def test_scalar_median_equals_geometric_median_on_rank1():
    """On rank-1 payload stacks (all clients share one bank direction,
    scaled by their rho), the geometric median IS the weighted-median
    scalar times the direction — the two rules agree to Weiszfeld
    tolerance, at O(K) vs O(K*M) cost."""
    from repro.core.lbgm import _block_layout
    rng = np.random.RandomState(7)
    K = 9
    params = {"w": jnp.asarray(rng.randn(11, 13).astype(np.float32))}
    k_frac = 0.3
    nb, block, kb = _block_layout(11 * 13, k_frac)
    idx = np.stack([np.sort(rng.choice(block, kb, replace=False))
                    for _ in range(nb)]).astype(np.int32)
    val = rng.randn(nb, kb).astype(np.float32)
    w = rng.rand(K).astype(np.float32)
    w /= w.sum()
    rho = (rng.rand(K) * 4 - 1).astype(np.float32)
    send = {"w": {"idx": jnp.broadcast_to(jnp.asarray(idx), (K, nb, kb)),
                  "val": jnp.broadcast_to(jnp.asarray(val), (K, nb, kb))}}
    gscale = jnp.asarray(rho)
    sm = ScalarMedianSparseAggregator(ScalarMedian(), params, k_frac)
    out_sm = sm.reduce(jnp.asarray(w), (send, gscale))
    from repro.fed.robust import CollectSparseAggregator
    # plenty of iterations: this seed's weight masses nearly balance at
    # the median (cum hits 0.4988 just below it), which is Weiszfeld's
    # slowest regime
    gm = CollectSparseAggregator(GeometricMedian(iters=1000, eps=1e-9),
                                 params, k_frac)
    out_gm = gm.reduce(jnp.asarray(w), (send, gscale))
    np.testing.assert_allclose(np.asarray(out_sm["w"]),
                               np.asarray(out_gm["w"]),
                               rtol=2e-3, atol=2e-3)


def test_scalar_median_engine_runs_and_needs_sparse_path(fcn_setup):
    for kw in (TOPK_SCHED["vmap"], TOPK_SCHED["sharded"]):
        h = run_rounds(make_engine(fcn_setup, codec="int8",
                                   aggregator="scalar_median",
                                   **GOLDEN_BASE, **kw))
        assert all(np.isfinite(e["loss"]) for e in h)
    with pytest.raises(ValueError, match="scalar"):
        make_engine(fcn_setup, aggregator="scalar_median", **GOLDEN_BASE)


# -------------------------------------------------- experiment/bench glue


def test_experiment_history_carries_wire_keys(fcn_setup):
    from benchmarks.common import build_spec, spec_metadata
    spec = build_spec(num_clients=4, n_data=320, n_eval=80, codec="int8",
                      use_lbgm=True, delta_threshold=0.2,
                      lbg_variant="topk", lbg_kw={"k_frac": 0.25})
    from repro.fed import run_experiment
    res = run_experiment(spec, rounds=2)
    for rec in res.records:
        assert rec.wire_bytes > 0 and rec.total_wire_bytes > 0
    assert res.history[-1]["wire_savings"] == res.records[-1].wire_savings
    meta = spec_metadata(spec)
    assert meta["codec"] == "int8" and "kernel_variant" in meta
