import os

# tests run on the single host CPU device (the 512-device override is
# exclusively for launch/dryrun.py, per the brief)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
