"""RWKV6 + RG-LRU: chunked/parallel forms vs sequential oracles, and
train->decode state continuity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.ref import rwkv6_scan_ref
from repro.models.rglru import _rglru_scan, apply_rglru, init_rglru
from repro.models.rwkv6 import apply_rwkv6, chunked_wkv, init_rwkv6
from repro.models.common import ParamStore


def test_chunked_wkv_matches_stepwise_oracle(key):
    B, T, H, hd = 2, 128, 2, 16
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) * 0.5
               for i in range(3))
    logw = -0.8 * jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.5
    out, S = chunked_wkv(r, k, v, logw, u, chunk=32)
    flat = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    ref = rwkv6_scan_ref(flat(r), flat(k), flat(v), flat(logw), uf)
    ref = ref.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_rwkv6_decode_continues_train_state(key):
    """Full-sequence apply == prefix apply + per-token decode steps."""
    cfg = get_config("rwkv6-3b").reduced()
    store = ParamStore(key, jnp.float32)
    init_rwkv6(store, "m", cfg)
    p = {k[len("m/"):]: v for k, v in store.params.items()}
    B, T, d = 1, 16, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(key, 7), (B, T, d)) * 0.3

    full, _ = apply_rwkv6(p, x, cfg)
    half, (S, last) = apply_rwkv6(p, x[:, :8], cfg)
    outs = [half]
    state, prev = S, last
    for t in range(8, T):
        o, (state, prev) = apply_rwkv6(p, x[:, t:t + 1], cfg,
                                       state=state, shifted=prev)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_rglru_scan_matches_sequential(key):
    B, T, d = 2, 64, 8
    a = jax.nn.sigmoid(jax.random.normal(key, (B, T, d)))
    bx = jax.random.normal(jax.random.fold_in(key, 1), (B, T, d))
    h = _rglru_scan(a, bx)
    ref = np.zeros((B, T, d), np.float32)
    hp = np.zeros((B, d), np.float32)
    an, bn = np.asarray(a), np.asarray(bx)
    for t in range(T):
        hp = an[:, t] * hp + bn[:, t]
        ref[:, t] = hp
    np.testing.assert_allclose(np.asarray(h), ref, rtol=1e-4, atol=1e-4)


def test_rglru_decode_continues_train_state(key):
    cfg = get_config("recurrentgemma-2b").reduced()
    store = ParamStore(key, jnp.float32)
    init_rglru(store, "m", cfg)
    p = {k[len("m/"):]: v for k, v in store.params.items()}
    B, T, d = 1, 12, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(key, 3), (B, T, d)) * 0.3
    full, _ = apply_rglru(p, x, cfg)
    half, (h, conv) = apply_rglru(p, x[:, :6], cfg)
    outs = [half]
    for t in range(6, T):
        o, (h, conv) = apply_rglru(p, x[:, t:t + 1], cfg,
                                   state=h, conv_state=conv)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
