"""Hierarchical (edge -> region -> global) aggregation tiers.

Acceptance (ISSUE 10 tentpole):
  * ``FLConfig.tiers`` leaves every round history *bit-for-bit* the flat
    fold (the wrapper replays the inner aggregator's fold verbatim on an
    untouched flat carry) on the vmap, chunked and buffered schedulers,
    for both the mean path and — accounting-only — robust rules/codec;
  * the combined edge partials match the flat carry at fp32 tolerance
    (the tree fold a physical deployment executes);
  * the :class:`~repro.comm.accounting.CommLedger` attributes per-tier
    wire bytes: the edge tier carries the real client payload bytes, the
    upstream tiers one dense fp32 partial carry per active aggregator.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.tree_math import tree_size
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_label_skew
from repro.fed.engine import DenseAggregator, SparseTopKAggregator
from repro.fed.hierarchy import HierarchicalAggregator, TierMap, make_tier_map
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn


@pytest.fixture(scope="module")
def fcn_setup():
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(1200, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=8, **flkw):
    params, x, y, loss_fn = fcn_setup
    flkw.setdefault("use_lbgm", True)
    flkw.setdefault("lbg_variant", "topk")
    flkw.setdefault("lbg_kw", {"k_frac": 0.1})
    flkw.setdefault("delta_threshold", 0.5)
    parts = partition_label_skew(y, K, 3, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             chunk_size=4, **flkw))


def run_rounds(fl, n=3, seed=0):
    rng = np.random.RandomState(seed)
    for _ in range(n):
        fl.run_round(rng)
    return fl


def assert_same_run(fl_a, fl_b):
    assert len(fl_a.history) == len(fl_b.history)
    for ra, rb in zip(fl_a.history, fl_b.history):
        assert ra.keys() == rb.keys()
        for k in ra:
            assert ra[k] == rb[k], (k, ra[k], rb[k])
    for k in fl_a.params:
        np.testing.assert_array_equal(np.asarray(fl_a.params[k]),
                                      np.asarray(fl_b.params[k]), err_msg=k)


# ------------------------------------------------------------------ TierMap

def test_tier_map_contiguous_balanced():
    tm = TierMap(10, [4])
    # floor(k*E/K): balanced within one, in client order
    np.testing.assert_array_equal(tm.edge_of,
                                  [0, 0, 0, 1, 1, 2, 2, 2, 3, 3])
    assert tm.region_of is None
    tm2 = TierMap(8, [4, 2])
    np.testing.assert_array_equal(tm2.edge_of, [0, 0, 1, 1, 2, 2, 3, 3])
    np.testing.assert_array_equal(tm2.region_of, [0, 0, 1, 1])


def test_tier_map_shuffle_is_seeded_permutation():
    a = TierMap(32, [8], assign="shuffle", seed=3)
    b = TierMap(32, [8], assign="shuffle", seed=3)
    c = TierMap(32, [8], assign="shuffle", seed=4)
    np.testing.assert_array_equal(a.edge_of, b.edge_of)
    assert not np.array_equal(a.edge_of, c.edge_of)
    # a permutation of the contiguous split: same edge sizes
    flat = TierMap(32, [8]).edge_of
    np.testing.assert_array_equal(np.bincount(a.edge_of, minlength=8),
                                  np.bincount(flat, minlength=8))


def test_tier_map_padding_and_validation():
    tm = TierMap(5, [2])
    ids = tm.edge_ids_padded(8)
    np.testing.assert_array_equal(ids[:5], tm.edge_of)
    np.testing.assert_array_equal(ids[5:], 0)
    with pytest.raises(ValueError):
        TierMap(8, [2, 2, 2])
    with pytest.raises(ValueError):
        TierMap(8, [4], assign="roundrobin")


def test_tier_map_round_bytes():
    tm = TierMap(8, [4, 2])
    # all clients active: 4 edges and both regions ship one carry each
    b = tm.round_bytes(np.ones(8), payload_bytes=100.0, carry_bytes=40.0)
    assert b == {"edge": 100.0, "region": 160.0, "global": 80.0}
    # only clients 0-1 active -> edge 0 -> region 0
    act = np.zeros(8)
    act[:2] = 1
    b = tm.round_bytes(act, 10.0, 40.0)
    assert b == {"edge": 10.0, "region": 40.0, "global": 40.0}
    # nobody active: upstream links idle
    b = tm.round_bytes(np.zeros(8), 0.0, 40.0)
    assert b == {"edge": 0.0, "region": 0.0, "global": 0.0}
    # one-level spelling: edges ship straight to global
    tm1 = TierMap(8, [4])
    b = tm1.round_bytes(np.ones(8), 100.0, 40.0)
    assert b == {"edge": 100.0, "global": 160.0}


def test_make_tier_map_spellings(fcn_setup):
    cfg = FLConfig(num_clients=8, tiers=[4, 2])
    tm = make_tier_map(cfg)
    assert (tm.n_edges, tm.n_regions, tm.assign) == (4, 2, "contiguous")
    cfg = FLConfig(num_clients=8,
                   tiers={"levels": [4], "assign": "shuffle"})
    tm = make_tier_map(cfg)
    assert (tm.n_edges, tm.n_regions, tm.assign) == (4, None, "shuffle")
    assert make_tier_map(FLConfig(num_clients=8)) is None


def test_flconfig_tiers_validation():
    with pytest.raises(ValueError, match="tiers"):
        FLConfig(num_clients=8, tiers=[16])          # more edges than K
    with pytest.raises(ValueError, match="tiers"):
        FLConfig(num_clients=8, tiers=[2, 4])        # not descending
    with pytest.raises(ValueError, match="tiers"):
        FLConfig(num_clients=8, tiers={"levels": [4], "assign": "zigzag"})
    with pytest.raises(ValueError, match="tiers"):
        FLConfig(num_clients=8, tiers={"levels": [4], "typo": 1})
    with pytest.raises(ValueError, match="sharded"):
        FLConfig(num_clients=8, tiers=[4], scheduler="sharded",
                 use_lbgm=True, lbg_variant="topk-sharded")


# ------------------------------------------- aggregator-level equivalence

def _fold(agg, acc, w, payload, chunk):
    n = w.shape[0]
    for s in range(0, n, chunk):
        sl = slice(s, s + chunk)
        out = (jax.tree.map(lambda a: a[sl], payload[0]), payload[1][sl]) \
            if isinstance(payload, tuple) \
            else jax.tree.map(lambda a: a[sl], payload)
        acc = agg.accumulate(acc, w[sl], out)
    return acc


@pytest.mark.parametrize("sparse", [False, True])
def test_wrapper_flat_carry_is_bit_for_bit(sparse):
    rng = np.random.RandomState(0)
    K, E = 12, 3
    params = {"w": jnp.zeros(64, jnp.float32)}
    w = jnp.asarray(rng.rand(K).astype(np.float32))
    if sparse:
        inner = SparseTopKAggregator(params, k_frac=0.1)
        (_, _, nb, block) = inner._layout["w"]
        kb = max(1, int(np.ceil(0.1 * block)))
        # unique in-row indices (top-k payloads never repeat a position)
        idx = np.stack([np.stack([
            rng.choice(block, size=kb, replace=False)
            for _ in range(nb)]) for _ in range(K)])
        send = {"w": {"idx": jnp.asarray(idx, jnp.int32),
                      "val": jnp.asarray(
                          rng.randn(K, nb, kb).astype(np.float32))}}
        payload = (send, jnp.ones(K, jnp.float32))
    else:
        inner = DenseAggregator()
        payload = {"w": jnp.asarray(rng.randn(K, 64).astype(np.float32))}
    tm = TierMap(K, [E])
    hier = HierarchicalAggregator(inner, tm.edge_ids_padded(K), E)
    a_flat = _fold(inner, inner.init(params), w, payload, chunk=4)
    a_hier = _fold(hier, hier.init(params), w, payload, chunk=4)
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        inner.finalize(a_flat), hier.finalize(a_hier))
    # the physical tree combine of edge partials: fp32 tolerance
    jax.tree.map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6),
        a_flat, hier.combine_edges(a_hier))
    # each edge partial only holds its own clients' mass
    edges = hier.edge_partials(a_hier)
    for e in range(E):
        own = np.asarray(tm.edge_of) == e
        w_e = jnp.where(jnp.asarray(own), w, 0.0)
        ref = _fold(inner, inner.init(params), w_e, payload, chunk=4)
        jax.tree.map(
            lambda x, y, e=e: np.testing.assert_allclose(
                np.asarray(x), np.asarray(y)[e], rtol=1e-5, atol=1e-6),
            ref, edges)


# ------------------------------------------------- engine-level invariance

@pytest.mark.parametrize("sched,extra", [
    ("chunked", {}),
    ("vmap", {}),
    ("chunked", {"sample_frac": 0.5}),
    ("chunked", {"tiers": {"levels": [4, 2], "assign": "shuffle"}}),
    ("buffered", {"latency": "fixed", "latency_kw": {"delay": 1}}),
])
def test_tiered_history_bit_for_bit_flat(fcn_setup, sched, extra):
    extra = dict(extra)
    tiers = extra.pop("tiers", [4, 2])
    flat = run_rounds(make_engine(fcn_setup, scheduler=sched, **extra))
    tier = run_rounds(make_engine(fcn_setup, scheduler=sched, tiers=tiers,
                                  **extra))
    assert tier._tiered_fold
    assert_same_run(flat, tier)


def test_tiered_accounting_only_paths(fcn_setup):
    # robust rules and lossy codecs keep the flat fold (a median of
    # medians is not the median; codec payloads are lossy) — the tier map
    # is accounting-only there, so histories stay exactly equal
    for extra in ({"aggregator": "median"}, {"codec": "int8"}):
        flat = run_rounds(make_engine(fcn_setup, scheduler="chunked",
                                      **extra))
        tier = run_rounds(make_engine(fcn_setup, scheduler="chunked",
                                      tiers=[4], **extra))
        assert not tier._tiered_fold
        assert tier.ledger.tier_wire_bytes  # bytes still attributed
        assert_same_run(flat, tier)


def test_ledger_tier_byte_attribution(fcn_setup):
    fl = run_rounds(make_engine(fcn_setup, K=8, scheduler="chunked",
                                tiers=[4, 2]), n=3)
    tb = fl.ledger.tier_wire_bytes
    assert set(tb) == {"edge", "region", "global"}
    # edge tier carries exactly the rounds' real payload bytes
    assert tb["edge"] == sum(h["wire_bytes"] for h in fl.history)
    # full participation: every edge and region ships one dense fp32
    # carry per round
    carry = 4.0 * tree_size(fl.params)
    assert tb["region"] == 3 * 4 * carry
    assert tb["global"] == 3 * 2 * carry
    # per-round ledger entries carry the same split
    for e in fl.ledger.per_round:
        assert set(e["tiers"]) == {"edge", "region", "global"}
    assert fl.ledger.summary()["tier_wire_bytes"] == tb


def test_ledger_tiers_roundtrip_state_dict(fcn_setup):
    fl = run_rounds(make_engine(fcn_setup, scheduler="chunked", tiers=[4]))
    from repro.comm.accounting import CommLedger
    fresh = CommLedger()
    fresh.load_state(fl.ledger.state_dict())
    assert fresh.state_dict() == fl.ledger.state_dict()
    assert fresh.tier_wire_bytes == fl.ledger.tier_wire_bytes
