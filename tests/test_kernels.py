"""Pallas kernel sweeps vs ref.py oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [17, 1000, 65536, 200_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lbgm_projection_sweep(key, n, dtype):
    g = (jax.random.normal(key, (n,)) * 0.1).astype(dtype)
    l = (jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.1
         ).astype(dtype)
    got = ops.lbgm_projection({"x": g}, {"x": l}, interpret=True)
    want = ref.lbgm_projection_ref(g, l)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3 if dtype == jnp.bfloat16 else 1e-4)


def test_raw_entry_points_default_interpret_to_backend_autodetect(key):
    """Regression (ISSUE 3): the raw ``*_pallas`` entry points hard-coded
    ``interpret=True``, silently running the interpreter on real TPUs for
    any caller bypassing ops.py. They must default to None -> backend
    auto-detection, same policy as the ops wrappers."""
    import inspect

    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.lbgm_projection import lbgm_projection_pallas
    from repro.kernels.rwkv6_scan import rwkv6_scan_pallas

    for fn in (lbgm_projection_pallas, flash_attention_pallas,
               rwkv6_scan_pallas):
        sig = inspect.signature(fn)
        assert sig.parameters["interpret"].default is None, fn
    # the auto default matches an explicit interpret on this backend
    g = jax.random.normal(key, (4096,))
    l = jax.random.normal(jax.random.fold_in(key, 1), (4096,))
    auto = lbgm_projection_pallas(g, l)
    explicit = lbgm_projection_pallas(g, l,
                                      interpret=ops._default_interpret())
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))
    assert ops._default_interpret() == (jax.default_backend() != "tpu")


def test_lbgm_projection_pytree(key):
    g = {"a": jax.random.normal(key, (100,)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (3, 7))}
    l = jax.tree.map(lambda x: x * 0.5, g)
    gl, gg, ll = ops.lbgm_projection(g, l, interpret=True)
    from repro.core.tree_math import tree_sq_norm, tree_vdot
    np.testing.assert_allclose(float(gl), float(tree_vdot(g, l)), rtol=1e-4)
    np.testing.assert_allclose(float(gg), float(tree_sq_norm(g)), rtol=1e-4)
    np.testing.assert_allclose(float(ll), float(tree_sq_norm(l)), rtol=1e-4)


@pytest.mark.parametrize("tq,tk", [(128, 128), (256, 256), (128, 384)])
@pytest.mark.parametrize("window", [None, 100])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(key, tq, tk, window, dtype):
    B, Hq, Hkv, hd = 1, 2, 1, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, tq, Hq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, tk, Hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, tk, Hkv, hd)).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
    g = Hq // Hkv
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(
        B * Hq, tk, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(
        B * Hq, tk, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, tq, hd)
    want = ref.flash_attention_ref(qf, kf, vf, causal=True, window=window)
    want = want.reshape(B, Hq, tq, hd).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("t", [64, 256])
@pytest.mark.parametrize("hd", [32, 64])
def test_rwkv6_scan_sweep(key, t, hd):
    B, H = 1, 2
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (B, t, H, hd)) * 0.5
               for i in range(3))
    logw = -0.7 * jax.nn.sigmoid(jax.random.normal(ks[3], (B, t, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.5
    got = ops.rwkv6_scan(r, k, v, logw, u, interpret=True)
    flat = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, t, hd)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    want = ref.rwkv6_scan_ref(flat(r), flat(k), flat(v), flat(logw), uf)
    want = want.reshape(B, H, t, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
