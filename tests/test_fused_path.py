"""Fused LBGM decision hot path + sparse scalar-round aggregation
(ISSUE 4 tentpole).

Four pillars:
  (a) the batched Pallas kernels (leading client-axis grid dimension)
      match the ``kernels/ref.py`` oracles in interpret mode, including
      under ``jax.vmap`` (the custom_vmap routing the schedulers rely on)
      and at non-tile-aligned sizes;
  (b) the bit-identical pad-row trims and the ``sparse_out`` client-step
      contract ((idx, val) payload + gscale, no dense scatter) agree with
      the legacy step;
  (c) engine-level: the sparse aggregation path equals the pre-PR dense
      path bit-for-bit on full rounds and within fp32 tolerance (with
      IDENTICAL uplink accounting) on scalar rounds, across
      vmap/chunked/sharded; ``fused_kernels=False`` restores the legacy
      path; ``fused_kernels=True`` (Pallas interpret off-TPU) agrees too;
  (d) the round prefetcher is numerically invisible and the vectorized
      batch sampler preserves the exact rng stream of the old per-client
      loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import lbgm as lbgm_lib
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_iid
from repro.kernels import ops, ref
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

# ------------------------------------------------------------- (a) kernels


@pytest.mark.parametrize("n", [257, 10_007, 65536])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_projection_matches_ref(key, n, dtype):
    B = 3
    g = (jax.random.normal(key, (B, n)) * 0.1).astype(dtype)
    l = (jax.random.normal(jax.random.fold_in(key, 1), (B, n)) * 0.1
         ).astype(dtype)
    from repro.kernels.lbgm_projection import lbgm_projection_batched_pallas
    gl, gg, ll = lbgm_projection_batched_pallas(g, l, interpret=True)
    tol = 5e-3 if dtype == jnp.bfloat16 else 1e-4
    for b in range(B):
        want = ref.lbgm_projection_ref(g[b], l[b])
        np.testing.assert_allclose(
            np.array([gl[b], gg[b], ll[b]]), np.asarray(want), rtol=tol)


def test_projection_vmap_routes_to_batched_kernel(key):
    """vmap over the client axis must hit the batched kernel (leading batch
    grid dim) and agree with per-client calls."""
    B, n = 4, 5000
    g = jax.random.normal(key, (B, n))
    l = jax.random.normal(jax.random.fold_in(key, 1), (B, n))
    got = jax.vmap(lambda a, b: ops.lbgm_projection(
        {"x": a}, {"x": b}, interpret=True))(g, l)
    for b in range(B):
        one = ops.lbgm_projection({"x": g[b]}, {"x": l[b]}, interpret=True)
        np.testing.assert_allclose(
            np.array([got[0][b], got[1][b], got[2][b]]),
            np.asarray(one), rtol=1e-5)


@pytest.mark.parametrize("nb,block,kb", [(1, 700, 33), (3, 512, 17),
                                         (16, 1000, 9)])
def test_sparse_decision_kernel_matches_ref(key, nb, block, kb):
    blocks = jax.random.normal(key, (nb, block))
    perm = jnp.argsort(
        jax.random.normal(jax.random.fold_in(key, 2), (nb, block)), axis=1)
    idx = perm[:, :kb].astype(jnp.int32)
    got = ops.lbgm_sparse_decision(blocks, idx, interpret=True)
    want = ref.lbgm_sparse_decision_ref(blocks, idx)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_sparse_decision_vmap_over_clients(key):
    B, nb, block, kb = 3, 2, 256, 11
    blocks = jax.random.normal(key, (B, nb, block))
    idx = jnp.tile(jnp.arange(kb, dtype=jnp.int32)[None, None],
                   (B, nb, 1))
    got = jax.vmap(lambda x, i: ops.lbgm_sparse_decision(
        x, i, interpret=True))(blocks, idx)
    for b in range(B):
        want = ref.lbgm_sparse_decision_ref(blocks[b], idx[b])
        for a, w in zip((got[0][b], got[1][b], got[2][b], got[3][b]), want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                       rtol=1e-5)


# ---------------------------------------------------- (b) step-level logic


def _rand_grad(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return {n: jax.random.normal(k, s)
            for k, (n, s) in zip(ks, shapes.items())}


#: fc1/w-like leaf spans >1 block so nb rounds up to 16 (pad rows live)
SHAPES = {"w": (700, 128), "b": (64,)}


def test_trim_pad_is_bit_identical(key):
    g = _rand_grad(key, SHAPES)["w"]
    assert lbgm_lib._block_layout(g.size, 0.1)[0] == 16  # pad rows exist
    a = lbgm_lib.leaf_topk(g, 0.1)
    b = lbgm_lib.leaf_topk(g, 0.1, trim_pad=True)
    np.testing.assert_array_equal(np.asarray(a["idx"]), np.asarray(b["idx"]))
    np.testing.assert_array_equal(np.asarray(a["val"]), np.asarray(b["val"]))
    ga = lbgm_lib.leaf_sparse_gather(g, a, 0.1)
    gb = lbgm_lib.leaf_sparse_gather(g, a, 0.1, trim_pad=True)
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(gb))


@pytest.mark.parametrize("delta", [-1.0, 0.5, 1.0])
def test_sparse_out_contract_matches_dense_step(key, delta):
    """(send, gscale) must reproduce the dense g_tilde: scatter(send) *
    gscale == g_tilde, new_lbg/stats identical."""
    k_frac = 0.1
    g = _rand_grad(key, SHAPES)
    lbg = lbgm_lib.init_topk_lbg(g, k_frac)
    # a refreshed bank (so the recycle branch can fire for delta=1.0)
    _, lbg, _ = lbgm_lib.lbgm_topk_client_step(
        _rand_grad(jax.random.fold_in(key, 7), SHAPES), lbg, -1.0, k_frac)
    gt, nl, st = lbgm_lib.lbgm_topk_client_step(g, lbg, delta, k_frac)
    (send, gscale), nl2, st2 = lbgm_lib.lbgm_topk_client_step(
        g, lbg, delta, k_frac, sparse_out=True)
    for a, b in zip(jax.tree.leaves((nl, tuple(st))),
                    jax.tree.leaves((nl2, tuple(st2)))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if bool(st.sent_scalar):
        np.testing.assert_allclose(float(gscale), float(st.rho), rtol=1e-6)
    else:
        assert float(gscale) == 1.0
    for name in g:
        dense = lbgm_lib.leaf_scatter(send[name], g[name].shape,
                                      g[name].size, k_frac)
        np.testing.assert_allclose(np.asarray(dense) * float(gscale),
                                   np.asarray(gt[name]), rtol=1e-5,
                                   atol=1e-7)


def test_topk_step_fused_matches_unfused(key):
    k_frac = 0.1
    g = _rand_grad(key, SHAPES)
    lbg = lbgm_lib.init_topk_lbg(g, k_frac)
    _, lbg, _ = lbgm_lib.lbgm_topk_client_step(
        _rand_grad(jax.random.fold_in(key, 7), SHAPES), lbg, -1.0, k_frac)
    gt_a, nl_a, st_a = lbgm_lib.lbgm_topk_client_step(g, lbg, 0.5, k_frac)
    gt_b, nl_b, st_b = lbgm_lib.lbgm_topk_client_step(g, lbg, 0.5, k_frac,
                                                      fused=True)
    assert bool(st_a.sent_scalar) == bool(st_b.sent_scalar)
    for a, b in zip(jax.tree.leaves((gt_a, nl_a)),
                    jax.tree.leaves((gt_b, nl_b))):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(st_a.sin2), float(st_b.sin2),
                               rtol=1e-4, atol=1e-6)


def test_dense_client_step_fused_matches_unfused(key):
    g = _rand_grad(key, SHAPES)
    lbg = _rand_grad(jax.random.fold_in(key, 3), SHAPES)
    gt_a, nl_a, st_a = lbgm_lib.lbgm_client_step(g, lbg, 0.5)
    gt_b, nl_b, st_b = lbgm_lib.lbgm_client_step(g, lbg, 0.5, fused=True)
    assert bool(st_a.sent_scalar) == bool(st_b.sent_scalar)
    for a, b in zip(jax.tree.leaves((gt_a, nl_a)),
                    jax.tree.leaves((gt_b, nl_b))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


# ----------------------------------------------- (c) engine round parity


@pytest.fixture(scope="module")
def fcn_setup():
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(600, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=6, **flkw):
    params, x, y, loss_fn = fcn_setup
    parts = partition_iid(len(y), K, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    base = dict(num_clients=K, tau=2, lr=0.05, batch_size=8,
                use_lbgm=True, lbg_variant="topk", lbg_kw={"k_frac": 0.1})
    base.update(flkw)
    return FLEngine(loss_fn, params, data, FLConfig(**base))


SCHED_KW = {
    "vmap": {},
    "chunked": {"chunk_size": 3},
    "sharded": {"chunk_size": 3, "mesh": 1, "lbg_variant": "topk-sharded"},
}


@pytest.mark.parametrize("sched", ["vmap", "chunked", "sharded"])
def test_sparse_agg_equals_dense_full_rounds_bitforbit(fcn_setup, sched):
    """delta=-1 -> every round full: the sparse aggregation path must be
    bit-for-bit identical to the pre-PR dense-scatter path."""
    kw = dict(delta_threshold=-1.0, scheduler=sched, **SCHED_KW[sched])
    fl_d = make_engine(fcn_setup, fused_kernels=False, **kw)
    fl_s = make_engine(fcn_setup, **kw)
    assert not fl_d._sparse_agg and fl_s._sparse_agg
    hd = fl_d.run(3)
    hs = fl_s.run(3)
    assert hd == hs
    for k in fl_d.params:
        np.testing.assert_array_equal(np.asarray(fl_d.params[k]),
                                      np.asarray(fl_s.params[k]), err_msg=k)


@pytest.mark.parametrize("sched", ["vmap", "chunked", "sharded"])
def test_sparse_agg_equals_dense_scalar_rounds_fp32(fcn_setup, sched):
    """delta=1 -> every post-refresh round recycles: fp32 tolerance
    (w*rho folds before the scatter) with IDENTICAL uplink accounting."""
    kw = dict(delta_threshold=1.0, scheduler=sched, **SCHED_KW[sched])
    fl_d = make_engine(fcn_setup, fused_kernels=False, **kw)
    fl_s = make_engine(fcn_setup, **kw)
    hd = fl_d.run(4)
    hs = fl_s.run(4)
    assert hs[-1]["frac_scalar"] == 1.0          # the regime under test
    for a, b in zip(hd, hs):
        assert a["uplink_floats"] == b["uplink_floats"]
        assert a["frac_scalar"] == b["frac_scalar"]
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)
    for k in fl_d.params:
        np.testing.assert_allclose(np.asarray(fl_d.params[k]),
                                   np.asarray(fl_s.params[k]),
                                   rtol=1e-5, atol=1e-7, err_msg=k)


@pytest.mark.slow
def test_fused_true_interpret_engine_agrees(fcn_setup):
    """fused_kernels=True off-TPU runs the Pallas kernels in interpret
    mode inside the jitted round (vmap within chunks) — numerics must stay
    within fp32 tolerance of the legacy path, uplink identical."""
    kw = dict(delta_threshold=0.5, scheduler="chunked", chunk_size=3)
    fl_d = make_engine(fcn_setup, fused_kernels=False, **kw)
    fl_f = make_engine(fcn_setup, fused_kernels=True, **kw)
    assert fl_f.store.fused
    hd = fl_d.run(2)
    hf = fl_f.run(2)
    for a, b in zip(hd, hf):
        assert a["uplink_floats"] == b["uplink_floats"]
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)
    for k in fl_d.params:
        np.testing.assert_allclose(np.asarray(fl_d.params[k]),
                                   np.asarray(fl_f.params[k]),
                                   rtol=1e-5, atol=1e-7, err_msg=k)


def test_aggregator_selection_and_knob(fcn_setup):
    from repro.fed.engine import (DenseAggregator, SparseTopKAggregator,
                                  resolve_fused_kernels)
    # dense store has no sparse payload -> dense aggregation regardless
    fl = make_engine(fcn_setup, lbg_variant="dense")
    assert isinstance(fl.agg, DenseAggregator) and not fl._sparse_agg
    # topk store defaults to sparse aggregation...
    fl = make_engine(fcn_setup)
    assert isinstance(fl.agg, SparseTopKAggregator) and fl._sparse_agg
    # ...unless the knob pins the legacy path
    fl = make_engine(fcn_setup, fused_kernels=False)
    assert isinstance(fl.agg, DenseAggregator)
    assert not fl.store.fused
    # Pallas auto-resolution follows the backend
    cfg = FLConfig(fused_kernels=None)
    assert resolve_fused_kernels(cfg) == (jax.default_backend() == "tpu")
    assert resolve_fused_kernels(FLConfig(fused_kernels=True)) is True


def test_fused_knob_validation_and_json_roundtrip():
    from repro.fed import ExperimentSpec
    with pytest.raises(ValueError, match="fused_kernels"):
        FLConfig(fused_kernels="yes")
    # int 0/1 compare == to False/True but would slip past the engine's
    # `is not False` aggregator gate — must be rejected, not coerced
    with pytest.raises(ValueError, match="fused_kernels"):
        FLConfig(fused_kernels=0)
    with pytest.raises(ValueError, match="fused_kernels"):
        FLConfig(fused_kernels=1)
    for v in (None, True, False):
        cfg = FLConfig(fused_kernels=v)
        assert FLConfig.from_dict(cfg.to_dict()) == cfg
        spec = ExperimentSpec(fl=cfg)
        again = ExperimentSpec.from_json(spec.to_json())
        assert again == spec and again.fl.fused_kernels is v


# -------------------------------------------------- (d) host-side pipeline


def test_prefetched_run_matches_sync_bitforbit(fcn_setup):
    fl_a = make_engine(fcn_setup, delta_threshold=0.2, scheduler="chunked",
                       chunk_size=3, sample_frac=0.7)
    fl_b = make_engine(fcn_setup, delta_threshold=0.2, scheduler="chunked",
                       chunk_size=3, sample_frac=0.7)
    ha = fl_a.run(4, prefetch=False)
    hb = fl_b.run(4, prefetch=True)
    assert ha == hb
    for k in fl_a.params:
        np.testing.assert_array_equal(np.asarray(fl_a.params[k]),
                                      np.asarray(fl_b.params[k]))


def test_vectorized_sampling_preserves_rng_stream(fcn_setup):
    """The one-gather sampler must consume the rng exactly like the old
    per-client loop (same draws, same order, same values)."""
    fl = make_engine(fcn_setup, K=5)
    rng = np.random.RandomState(42)
    got = fl._sample_batches(rng)
    # reference: the pre-PR per-client loop
    ref_rng = np.random.RandomState(42)
    out = None
    for d in fl.client_data:
        n = len(next(iter(d.values())))
        idx = ref_rng.randint(0, n, size=(fl.cfg.tau, fl.cfg.batch_size))
        picked = {k: v[idx] for k, v in d.items()}
        if out is None:
            out = {k: [] for k in picked}
        for k, v in picked.items():
            out[k].append(v)
    want = {k: np.stack(v) for k, v in out.items()}
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), want[k])
    # and the stream position afterwards is identical
    np.testing.assert_array_equal(rng.rand(5), ref_rng.rand(5))


def test_prefetcher_surfaces_thread_errors(fcn_setup):
    fl = make_engine(fcn_setup, K=4)
    pf = fl.prefetcher(np.random.RandomState(0))
    try:
        pf.next()  # a good round first
        fl._data_cat = None  # poison the sampler -> thread must fail
        with pytest.raises(RuntimeError, match="prefetch"):
            while True:
                pf.next()
        # a dead producer must keep raising, not hang on the empty queue
        with pytest.raises(RuntimeError, match="prefetch"):
            pf.next()
    finally:
        fl._data_cat = {}
        pf.close()


def test_prefetcher_next_after_close_raises(fcn_setup):
    fl = make_engine(fcn_setup, K=4)
    pf = fl.prefetcher(np.random.RandomState(0))
    pf.next()
    pf.close()
    with pytest.raises(RuntimeError, match="close"):
        pf.next()


def test_lbg_kw_reserved_key_actionable_error(fcn_setup):
    with pytest.raises(ValueError, match="fused_kernels"):
        make_engine(fcn_setup, lbg_kw={"k_frac": 0.1, "fused": True})
    # the 2-D mesh knobs are engine-controlled too (FLConfig.mesh)
    with pytest.raises(ValueError, match="FLConfig.mesh"):
        make_engine(fcn_setup, lbg_variant="topk-sharded",
                    lbg_kw={"k_frac": 0.1, "n_model": 2})
    with pytest.raises(ValueError, match="FLConfig.mesh"):
        make_engine(fcn_setup, lbg_variant="topk-sharded",
                    lbg_kw={"k_frac": 0.1, "model_axis": "x"})


# ---------------------------------- (e) two-pass threshold-select fallback


@pytest.mark.parametrize("nb,block,kb", [(1, 700, 33), (3, 512, 17),
                                         (16, 1000, 9), (4, 256, 256)])
def test_two_pass_kernel_matches_ref_setwise(key, nb, block, kb):
    """The Mosaic-safety variant (no in-kernel top_k / take_along_axis)
    must select the exact same (idx, val) SET per block row as the sorted
    oracle — slot order is by index, so compare through the canonical
    form — with the gathered values and ||g||^2 agreeing too."""
    from repro.kernels.lbgm_sparse import \
        lbgm_sparse_decision_two_pass_pallas
    blocks = jax.random.normal(key, (nb, block))
    perm = jnp.argsort(
        jax.random.normal(jax.random.fold_in(key, 2), (nb, block)), axis=1)
    idx = perm[:, :kb].astype(jnp.int32)
    gg, gath, ti, tv = lbgm_sparse_decision_two_pass_pallas(
        blocks, idx, interpret=True)
    rgg, rgath, rti, rtv = ref.lbgm_sparse_decision_ref(blocks, idx)
    np.testing.assert_allclose(float(gg), float(rgg), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(gath), np.asarray(rgath))
    si, sv = ref.sort_topk_rows(ti, tv)
    ri, rv = ref.sort_topk_rows(rti, rtv)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))


def test_two_pass_kernel_tiny_magnitudes(key):
    """Regression: the bit-space bisection must resolve rows whose
    |values| are far below any absolute float resolution (a float-interval
    bisection left such rows entirely inside the tie band and selected by
    index instead of magnitude)."""
    from repro.kernels.lbgm_sparse import \
        lbgm_sparse_decision_two_pass_pallas
    for scale in (1e-20, 1e-35, 1e30):
        blocks = jax.random.normal(key, (2, 256)) * scale
        idx = jnp.tile(jnp.arange(4, dtype=jnp.int32)[None], (2, 1))
        _, gath, ti, tv = lbgm_sparse_decision_two_pass_pallas(
            blocks, idx, interpret=True)
        _, rgath, rti, rtv = ref.lbgm_sparse_decision_ref(blocks, idx)
        np.testing.assert_array_equal(np.asarray(gath), np.asarray(rgath))
        si, sv = ref.sort_topk_rows(ti, tv)
        ri, rv = ref.sort_topk_rows(rti, rtv)
        np.testing.assert_array_equal(np.asarray(si), np.asarray(ri),
                                      err_msg=f"scale={scale}")
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))


def test_two_pass_kernel_degenerate_rows(key):
    """All-zero rows and rows with fewer nonzeros than kb: the threshold
    collapses to 0 and the tie-fill must keep every nonzero plus the
    lowest-index zeros — exactly lax.top_k's tie rule."""
    from repro.kernels.lbgm_sparse import \
        lbgm_sparse_decision_two_pass_pallas
    z = jnp.zeros((2, 300))
    z = z.at[1, 250].set(3.0).at[1, 7].set(-2.0)
    idx = jnp.tile(jnp.arange(5, dtype=jnp.int32)[None], (2, 1))
    gg, gath, ti, tv = lbgm_sparse_decision_two_pass_pallas(
        z, idx, interpret=True)
    want = ref.lbgm_sparse_decision_ref(z, idx)
    np.testing.assert_array_equal(np.asarray(gath), np.asarray(want[1]))
    si, sv = ref.sort_topk_rows(ti, tv)
    ri, rv = ref.sort_topk_rows(want[2], want[3])
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))
    # the nonzeros at positions 7 and 250 both survive the index-order fill
    assert {7, 250} <= set(np.asarray(ti[1]).tolist())


def test_two_pass_vmap_and_env_knob(key, monkeypatch):
    """vmap routes to the batched two-pass grid; REPRO_LBGM_TWO_PASS_TOPK
    flips the ops-level default without touching any config."""
    from repro.kernels.ops import TWO_PASS_ENV, _default_two_pass
    B, nb, block, kb = 3, 2, 256, 11
    blocks = jax.random.normal(key, (B, nb, block))
    idx = jnp.tile(jnp.arange(kb, dtype=jnp.int32)[None, None], (B, nb, 1))
    got = jax.vmap(lambda x, i: ops.lbgm_sparse_decision(
        x, i, interpret=True, two_pass=True))(blocks, idx)
    for b in range(B):
        want = ref.lbgm_sparse_decision_ref(blocks[b], idx[b])
        np.testing.assert_allclose(float(got[0][b]), float(want[0]),
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(got[1][b]),
                                      np.asarray(want[1]))
        si, sv = ref.sort_topk_rows(got[2][b], got[3][b])
        ri, rv = ref.sort_topk_rows(want[2], want[3])
        np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))
    monkeypatch.delenv(TWO_PASS_ENV, raising=False)
    assert not _default_two_pass()
    monkeypatch.setenv(TWO_PASS_ENV, "1")
    assert _default_two_pass()
    for off in ("false", "0", "off", "no", "False"):
        monkeypatch.setenv(TWO_PASS_ENV, off)
        assert not _default_two_pass(), off


def test_two_pass_step_level_agrees(key):
    """topk_step_core(fused=True) under the two-pass env knob: same
    accept/recycle decision and fp32-tolerance g_tilde vs the legacy
    step (bank sets match; element order inside a row may differ)."""
    import os
    from repro.kernels.ops import TWO_PASS_ENV
    k_frac = 0.1
    g = _rand_grad(key, SHAPES)
    lbg = lbgm_lib.init_topk_lbg(g, k_frac)
    _, lbg, _ = lbgm_lib.lbgm_topk_client_step(
        _rand_grad(jax.random.fold_in(key, 7), SHAPES), lbg, -1.0, k_frac)
    gt_a, _, st_a = lbgm_lib.lbgm_topk_client_step(g, lbg, 0.5, k_frac)
    old = os.environ.get(TWO_PASS_ENV)
    os.environ[TWO_PASS_ENV] = "1"
    try:
        gt_b, _, st_b = lbgm_lib.lbgm_topk_client_step(g, lbg, 0.5, k_frac,
                                                       fused=True)
    finally:
        if old is None:
            os.environ.pop(TWO_PASS_ENV, None)
        else:
            os.environ[TWO_PASS_ENV] = old
    assert bool(st_a.sent_scalar) == bool(st_b.sent_scalar)
    np.testing.assert_allclose(float(st_a.sin2), float(st_b.sin2),
                               rtol=1e-4, atol=1e-6)
    for name in g:
        np.testing.assert_allclose(np.asarray(gt_a[name]),
                                   np.asarray(gt_b[name]),
                                   rtol=1e-5, atol=1e-7)
