"""Declarative experiment API: spec serialization, registries, equivalence
with hand-wired FLEngine runs, sweep driver, and the CLI.

Acceptance pillars (ISSUE 2):
  (a) ExperimentSpec round-trips losslessly through dict/JSON, and a spec
      serialized + reloaded reproduces the same engine history on the same
      seed,
  (b) registries reject duplicates and give actionable unknown-key errors
      (listing registered names), same for FLConfig field validation,
  (c) run_experiment on a 4-client FCN spec reproduces a hand-wired
      FLEngine's history bit-for-bit,
  (d) the ``python -m repro.fed.run`` CLI applies ``--set`` overrides and
      emits a result JSON.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.fed import (ComponentSpec, EvalPolicy, ExperimentSpec, FLConfig,
                       build_experiment, run_experiment, sweep)
from repro.fed.registry import Registry
from repro.fed import registry as reg

ROUNDS = 4


def tiny_spec(**fl_overrides):
    fl_kw = dict(num_clients=4, tau=2, lr=0.05, batch_size=8, seed=0,
                 use_lbgm=True, delta_threshold=0.2)
    fl_kw.update(fl_overrides)
    return ExperimentSpec(
        name="tiny",
        model=ComponentSpec("fcn"),
        data=ComponentSpec("mixture", {"n": 240, "n_eval": 60, "seed": 0}),
        partition=ComponentSpec("label_skew",
                                {"classes_per_client": 3, "seed": 0}),
        fl=FLConfig(**fl_kw),
        rounds=ROUNDS,
        eval=EvalPolicy(every=2, final=True),
    )


# ------------------------------------------------- (a) spec serialization


def test_spec_dict_roundtrip_identity():
    spec = tiny_spec(compressor="topk", compressor_kw={"k_frac": 0.25},
                     lbg_variant="topk", lbg_kw={"k_frac": 0.1})
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec


def test_spec_json_roundtrip_identity(tmp_path):
    spec = tiny_spec()
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    path = tmp_path / "spec.json"
    spec.save(str(path))
    assert ExperimentSpec.load(str(path)) == spec


def test_spec_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown fields.*bogus"):
        ExperimentSpec.from_dict({"bogus": 1})
    with pytest.raises(ValueError, match="unknown fields.*delta"):
        FLConfig.from_dict({"delta": 0.2})


@pytest.mark.slow
def test_json_reload_reproduces_history():
    """Acceptance: a spec serialized to JSON and reloaded reproduces the
    same history on the same seed."""
    spec = tiny_spec()
    res_a = run_experiment(spec)
    res_b = run_experiment(ExperimentSpec.from_json(spec.to_json()))
    assert res_a.history == res_b.history
    assert res_a.final_eval == res_b.final_eval


# --------------------------------------------------------- (b) registries


def test_registry_duplicate_and_unknown_errors():
    r = Registry("widget")
    r.register("a", lambda: 1, aliases=("alpha",))
    with pytest.raises(ValueError, match="duplicate widget.*'a'"):
        r.register("a", lambda: 2)
    with pytest.raises(ValueError, match="duplicate widget alias"):
        r.register("b", lambda: 3, aliases=("alpha",))
    # a rejected registration must leave the registry untouched: the
    # corrected retry under the same name succeeds
    assert "b" not in r
    assert r.register("b", lambda: 3, aliases=("beta",))() == 3
    with pytest.raises(ValueError) as ei:
        r.get("nope")
    assert "'a'" in str(ei.value)  # error lists registered names
    assert r.get("alpha")() == 1
    assert "a" in r and "alpha" in r and "nope" not in r


def test_builtin_registries_populated():
    assert {"vmap", "chunked", "sharded"} <= set(reg.SCHEDULERS.names())
    assert {"dense", "topk", "topk-sharded", "null"} \
        <= set(reg.LBG_STORES.names())
    assert {"none", "topk", "atomo", "signsgd"} <= \
        set(reg.COMPRESSORS.names())
    assert {"fcn", "cnn"} <= set(reg.MODELS.names())
    assert "mixture" in reg.DATASETS
    assert {"iid", "label_skew"} <= set(reg.PARTITIONERS.names())


@pytest.mark.parametrize("bad,match", [
    (dict(sample_frac=0.0), r"sample_frac"),
    (dict(sample_frac=1.5), r"sample_frac"),
    (dict(chunk_size=0), r"chunk_size"),
    (dict(num_clients=0), r"num_clients"),
    (dict(scheduler="warp"), r"unknown scheduler.*vmap"),
    (dict(lbg_variant="bogus"), r"unknown lbg_variant.*dense"),
    (dict(compressor="zip"), r"unknown compressor.*signsgd"),
])
def test_flconfig_validation_actionable(bad, match):
    with pytest.raises(ValueError, match=match):
        FLConfig(**bad)


def test_stale_compressor_kw_actionable():
    """A sweep switching fl.compressor but keeping a stale compressor_kw
    must fail with the accepted kwargs, not a private-function TypeError."""
    from repro.compression import get_compressor
    with pytest.raises(ValueError, match="'signsgd'.*k_frac.*accepted"):
        get_compressor("signsgd", k_frac=0.1)
    assert get_compressor("topk", k_frac=0.1) is not None


def test_empty_held_out_with_eval_policy_rejected():
    spec = tiny_spec().with_overrides({"data.kw.n_eval": 0})
    with pytest.raises(ValueError, match="held-out split is empty"):
        build_experiment(spec)
    # disabling eval makes the same spec legal
    no_eval = dataclasses.replace(spec, eval=EvalPolicy(every=0, final=False))
    engine, _ = build_experiment(no_eval)
    assert engine.cfg.num_clients == 4


def test_spec_unknown_component_lists_registered():
    spec = tiny_spec()
    with pytest.raises(ValueError, match="unknown model.*fcn"):
        dataclasses.replace(spec,
                            model=ComponentSpec("resnet9000")).validate()
    with pytest.raises(ValueError, match="unknown dataset"):
        dataclasses.replace(spec, data=ComponentSpec("imagenet")).validate()


def test_with_overrides_dotted_keys():
    spec = tiny_spec()
    s2 = spec.with_overrides({"fl.delta_threshold": 0.4,
                              "data.kw.n": 120,
                              "model.kw.arch": "paper-fcn",
                              "rounds": 7})
    assert s2.fl.delta_threshold == 0.4 and s2.data.kw["n"] == 120
    assert s2.model.kw["arch"] == "paper-fcn" and s2.rounds == 7
    assert spec.fl.delta_threshold == 0.2  # original untouched
    with pytest.raises(ValueError, match="unknown override key"):
        spec.with_overrides({"fl.delta": 0.4})
    with pytest.raises(ValueError, match="unknown override key"):
        spec.with_overrides({"nope.x": 1})


# ------------------------------------- (c) equivalence with hand-wired run


def _hand_wired_engine():
    """Exactly what build_experiment does for tiny_spec, spelled out."""
    from repro.configs import get_config
    from repro.data.synthetic import mixture_classification
    from repro.fed import FLEngine, partition_label_skew
    from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(300, 10, seed=0)
    xt, yt = x[:240], y[:240]
    parts = partition_label_skew(yt, 4, 3, seed=0)
    data = [{"x": xt[p], "y": yt[p]} for p in parts]
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=4, tau=2, lr=0.05, batch_size=8,
                             seed=0, use_lbgm=True, delta_threshold=0.2))


def test_run_experiment_matches_flengine_bit_for_bit():
    res = run_experiment(tiny_spec())
    engine = _hand_wired_engine()
    ref_history = engine.run(ROUNDS)
    assert res.history == ref_history  # float-exact, every round
    assert res.total_uplink == engine.total_uplink
    assert res.vanilla_uplink == engine.vanilla_uplink


def test_model_kw_seed_overrides_fl_seed():
    spec = tiny_spec().with_overrides({"model.kw.seed": 3})
    engine, _ = build_experiment(spec)  # must not collide with fl.seed
    base, _ = build_experiment(tiny_spec())
    diffs = [float(np.abs(np.asarray(engine.params[k])
                          - np.asarray(base.params[k])).max())
             for k in engine.params]
    assert max(diffs) > 0  # a different init seed actually took effect


def test_build_experiment_returns_engine_and_eval():
    engine, eval_fn = build_experiment(tiny_spec())
    assert engine.cfg.num_clients == 4 and len(engine.client_data) == 4
    ev = eval_fn(engine.params)
    assert set(ev) == {"test_loss", "test_acc"}
    assert np.isfinite(ev["test_loss"])


def test_result_records_typed_and_serializable():
    res = run_experiment(tiny_spec())
    assert [r.round for r in res.records] == list(range(1, ROUNDS + 1))
    # eval ran at the policy's cadence (every=2) and nowhere else
    assert all(bool(r.eval) == (r.round % 2 == 0) for r in res.records)
    assert res.savings == res.records[-1].savings
    dumped = json.loads(json.dumps(res.to_dict()))
    assert dumped["spec"]["fl"]["num_clients"] == 4
    assert len(dumped["records"]) == ROUNDS


def test_sweep_grid_and_explicit_points():
    spec = dataclasses.replace(tiny_spec(), eval=EvalPolicy(final=False))
    results = sweep(spec, {"fl.delta_threshold": [-1.0, 0.95]}, rounds=3)
    assert [p["fl.delta_threshold"] for p, _ in results] == [-1.0, 0.95]
    # larger threshold recycles at least as often => no more uplink
    assert results[0][1].total_uplink >= results[1][1].total_uplink
    explicit = sweep(spec, [{"fl.tau": 1}], rounds=1)
    assert explicit[0][1].spec.fl.tau == 1


def test_lbgm_config_bridge_single_source_of_truth():
    from repro.configs.base import LBGMConfig
    lb = LBGMConfig(variant="topk", k_frac=0.05, num_clients=8,
                    local_steps=3, sample_frac=0.5)
    fl = lb.to_fl(batch_size=4)
    assert (fl.lbg_variant, fl.lbg_kw) == ("topk", {"k_frac": 0.05})
    assert (fl.num_clients, fl.tau, fl.sample_frac) == (8, 3, 0.5)
    assert fl.batch_size == 4
    # shared defaults are literally FLConfig's
    assert LBGMConfig().delta_threshold == FLConfig().delta_threshold
    assert LBGMConfig().enabled == FLConfig().use_lbgm


# ----------------------------------------------------------- (d) the CLI


def test_cli_smoke_with_set_overrides(tmp_path, capsys):
    from repro.fed import run as cli
    out = tmp_path / "result.json"
    rc = cli.main(["--rounds", "2",
                   "--set", "fl.num_clients=4",
                   "--set", "data.kw.n=160",
                   "--set", "data.kw.n_eval=40",
                   "--set", "eval.every=0",
                   "--set", "name=cli-smoke",
                   "--out", str(out)])
    assert rc == 0
    printed = capsys.readouterr().out
    assert "cli-smoke" in printed and "savings" in printed
    dumped = json.loads(out.read_text())
    assert dumped["rounds"] == 2
    assert dumped["spec"]["fl"]["num_clients"] == 4
    assert len(dumped["records"]) == 2


def test_cli_spec_file_and_print_spec(tmp_path, capsys):
    from repro.fed import run as cli
    path = tmp_path / "spec.json"
    tiny_spec().save(str(path))
    rc = cli.main(["--spec", str(path), "--print-spec",
                   "--set", "fl.lr=0.1"])
    assert rc == 0
    dumped = json.loads(capsys.readouterr().out)
    assert dumped["fl"]["lr"] == 0.1 and dumped["name"] == "tiny"


def test_cli_rejects_malformed_set():
    from repro.fed import run as cli
    with pytest.raises(SystemExit):
        cli.main(["--set", "no_equals_sign"])
