"""MoE routing: capacity dispatch, combine weights, degenerate cases."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models.common import ParamStore, silu
from repro.models.moe import apply_moe, init_moe


def _setup(key, E, top_k, cf=4.0, d=16, ff=32):
    cfg = dataclasses.replace(
        get_config("mixtral-8x22b").reduced(),
        d_model=d, d_ff=ff,
        moe=MoEConfig(num_experts=E, top_k=top_k, capacity_factor=cf))
    store = ParamStore(key, jnp.float32)
    init_moe(store, "moe", cfg)
    p = {k[len("moe/"):]: v for k, v in store.params.items()}
    return cfg, p


def test_single_expert_equals_dense_ffn(key):
    """E=1, top-1, ample capacity: MoE == its expert's SwiGLU exactly."""
    cfg, p = _setup(key, E=1, top_k=1, cf=2.0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    out, aux = apply_moe(p, x, cfg)
    g = jnp.einsum("btd,df->btf", x, p["w_gate"][0])
    u = jnp.einsum("btd,df->btf", x, p["w_up"][0])
    ref = jnp.einsum("btf,fd->btd", silu(g) * u, p["w_down"][0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_ample_capacity_no_drops(key):
    """With cf covering all tokens, every token receives its experts'
    output (output == weighted recompute, no zeros from drops)."""
    cfg, p = _setup(key, E=4, top_k=2, cf=8.0)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 16, cfg.d_model))
    out, _ = apply_moe(p, x, cfg)

    # dense recompute: run every expert on every token, combine via top-k
    logits = jnp.einsum("btd,de->bte", x, p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    g = jnp.einsum("btd,edf->ebtf", x, p["w_gate"])
    u = jnp.einsum("btd,edf->ebtf", x, p["w_up"])
    y = jnp.einsum("ebtf,efd->ebtd", silu(g) * u, p["w_down"])  # (E,B,T,d)
    sel = jnp.take_along_axis(
        y.transpose(1, 2, 0, 3), idx[..., None], axis=2)        # (B,T,k,d)
    ref = jnp.sum(sel * w[..., None].astype(sel.dtype), axis=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-4)


def test_tight_capacity_drops_but_finite(key):
    cfg, p = _setup(key, E=4, top_k=1, cf=0.5)
    x = jax.random.normal(jax.random.fold_in(key, 3), (1, 32, cfg.d_model))
    out, aux = apply_moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.isfinite(aux)) and float(aux) >= 0.0
    # some tokens dropped => some outputs exactly zero
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert float(jnp.min(norms)) == 0.0


def test_aux_loss_prefers_balance(key):
    """Uniform routing gives the minimum Switch aux loss (= coefficient)."""
    cfg, p = _setup(key, E=4, top_k=1)
    # force perfectly balanced hard routing via crafted logits
    x = jax.random.normal(jax.random.fold_in(key, 4), (1, 64, cfg.d_model))
    out, aux = apply_moe(p, x, cfg)
    assert float(aux) >= cfg.moe.router_aux_loss * 0.99 or float(aux) == 0.0
