"""Decode correctness: sequential serve_step over a ring cache reproduces the
training-path forward logits, per architecture family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.frontends import make_stub_embeds
from repro.models.transformer import forward, init_lm
from repro.serve.decode import init_decode_state, serve_step

DECODE_ARCHS = ["qwen3-1.7b", "rwkv6-3b", "recurrentgemma-2b",
                "mixtral-8x22b", "whisper-base", "qwen2-vl-2b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch, key):
    cfg = get_config(arch).reduced()
    if cfg.mrope:
        # decode path advances all three M-RoPE streams together, which
        # matches the text regime only => compare on a no-vision config
        cfg = dataclasses.replace(cfg, vision_tokens=0)
    if cfg.moe.num_experts:
        # train-path capacity drops are not replicated token-by-token in
        # decode; compare with ample capacity (no drops on either side)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params, _ = init_lm(key, cfg)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0,
                              cfg.vocab_size)
    extra = make_stub_embeds(key, cfg, B) if cfg.encdec else None

    logits_train, _ = forward(params, cfg, toks, extra)

    state, _ = init_decode_state(cfg, B, T)
    if cfg.encdec:
        # decode cross-attends the same encoder output the forward pass saw
        from repro.models.common import rms_norm, sinusoidal_positions
        from repro.models.transformer import _apply_block_train, subtree
        e = extra + sinusoidal_positions(extra.shape[1],
                                         cfg.d_model).astype(extra.dtype)
        for i in range(cfg.n_encoder_layers):
            e, _ = _apply_block_train(subtree(params, f"enc_{i:02d}"), e,
                                      cfg, "attn", None, causal_attn=False)
        state["enc_out"] = rms_norm(e, params["enc_norm"], cfg.norm_eps)

    outs = []
    step = jax.jit(lambda p, s, t: serve_step(p, cfg, s, t))
    for t in range(T):
        logits, state = step(params, state, toks[:, t:t + 1])
        outs.append(logits)
    logits_decode = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_decode, np.float32),
        np.asarray(logits_train, np.float32), rtol=5e-2, atol=5e-3)


def test_ring_buffer_wraps(key):
    """Cache shorter than the stream: behaves as sliding-window attention."""
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              sliding_window=8,
                              block_pattern=("swa",))
    params, _ = init_lm(key, cfg)
    B, T, W = 1, 24, 8
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    logits_train, _ = forward(params, cfg, toks)  # swa window=8

    state, _ = init_decode_state(cfg, B, W)
    step = jax.jit(lambda p, s, t: serve_step(p, cfg, s, t))
    last = None
    for t in range(T):
        last, state = step(params, state, toks[:, t:t + 1])
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(logits_train[:, -1], np.float32), rtol=5e-2, atol=5e-3)
