"""Attention correctness: chunking, GQA, windows, RoPE/M-RoPE, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attention, decode_attention, mrope_rotate,
                                    rope_rotate)


def _naive(q, k, v, causal=True, window=None, q_offset=0):
    B, Tq, Hq, hd = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
    qpos = jnp.arange(Tq)[:, None] + q_offset
    kpos = jnp.arange(Tk)[None, :]
    m = jnp.ones((Tq, Tk), bool)
    if causal:
        m &= qpos >= kpos
    if window is not None:
        m &= (qpos - kpos) < window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("tq", [64, 256])
def test_chunked_matches_naive(key, tq, window):
    B, Hq, Hkv, hd = 2, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, tq, Hq, hd))
    k = jax.random.normal(ks[1], (B, tq, Hkv, hd))
    v = jax.random.normal(ks[2], (B, tq, Hkv, hd))
    out = attention(q, k, v, causal=True, window=window, q_chunk=32)
    ref = _naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_non_divisible_chunk(key):
    q = jax.random.normal(key, (1, 96, 2, 8))
    out = attention(q, q, q, causal=False, q_chunk=64)  # 96 % 64 != 0
    ref = _naive(q, q, q, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_decode_matches_last_position(key):
    """decode_attention over a cache == full attention's last row."""
    B, T, Hq, Hkv, hd = 2, 32, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, Hq, hd))
    k = jax.random.normal(ks[1], (B, T, Hkv, hd))
    v = jax.random.normal(ks[2], (B, T, Hkv, hd))
    full = _naive(q, k, v, causal=True)
    dec = decode_attention(q[:, -1:], k, v, valid_len=T)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-5)


def test_rope_relative_shift_invariance(key):
    """RoPE attention logits depend only on relative positions."""
    B, T, H, hd = 1, 8, 1, 16
    q = jax.random.normal(key, (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, hd))
    p0 = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    p1 = p0 + 37
    s0 = jnp.einsum("bqhd,bkhd->bqk", rope_rotate(q, p0, 1e4),
                    rope_rotate(k, p0, 1e4))
    s1 = jnp.einsum("bqhd,bkhd->bqk", rope_rotate(q, p1, 1e4),
                    rope_rotate(k, p1, 1e4))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-4, atol=1e-4)


def test_mrope_equals_rope_for_text(key):
    """When the three position streams coincide, M-RoPE == RoPE."""
    B, T, H, hd = 2, 16, 2, 32
    x = jax.random.normal(key, (B, T, H, hd))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    pos3 = jnp.broadcast_to(pos[None], (3, B, T))
    a = rope_rotate(x, pos, 1e4)
    b = mrope_rotate(x, pos3, (4, 6, 6), 1e4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
