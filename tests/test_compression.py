"""Compression baselines: top-K, SignSGD, ATOMO, error feedback.

Deterministic only — the hypothesis property test lives in
test_compression_properties.py so this module stays collectible when the
dev-only `hypothesis` package is absent (requirements-dev.txt).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import atomo, error_feedback as ef, signsgd, topk
from repro.compression import get_compressor


def test_topk_keeps_largest_and_zeroes_rest():
    g = {"w": jnp.asarray([[1.0, -5.0], [0.1, 3.0]])}
    out, cost = topk.compress(g, k_frac=0.5)
    w = np.asarray(out["w"])
    assert w[0, 1] == -5.0 and w[1, 1] == 3.0
    assert w[0, 0] == 0.0 and w[1, 0] == 0.0
    assert float(cost) == 1.5 * 2


def test_signsgd_sign_and_scale():
    g = {"w": jnp.asarray([1.0, -2.0, 3.0, -4.0])}
    out, bits = signsgd.compress(g)
    w = np.asarray(out["w"])
    np.testing.assert_allclose(np.sign(w), np.sign(np.asarray(g["w"])))
    np.testing.assert_allclose(np.abs(w), 2.5)      # mean |g|
    assert float(bits) == 4 / 32 + 1


def test_atomo_rank_exactness():
    rng = np.random.RandomState(0)
    u = rng.randn(16, 2).astype(np.float32)
    v = rng.randn(2, 8).astype(np.float32)
    g = {"w": jnp.asarray(u @ v)}                   # exactly rank 2
    out2, _ = atomo.compress(g, rank=2)
    np.testing.assert_allclose(np.asarray(out2["w"]), u @ v,
                               rtol=1e-4, atol=1e-4)
    out1, _ = atomo.compress(g, rank=1)
    err1 = np.linalg.norm(np.asarray(out1["w"]) - u @ v)
    assert err1 > 1e-3                              # rank-1 lossy


def test_atomo_power_iteration_close_to_svd():
    rng = np.random.RandomState(1)
    g = {"w": jnp.asarray(rng.randn(32, 16).astype(np.float32))}
    svd_out, _ = atomo.compress(g, rank=4, method="svd")
    pow_out, _ = atomo.compress(g, rank=4, method="power",
                                key=jax.random.PRNGKey(0))
    e_svd = np.linalg.norm(np.asarray(svd_out["w"]) - np.asarray(g["w"]))
    e_pow = np.linalg.norm(np.asarray(pow_out["w"]) - np.asarray(g["w"]))
    assert e_pow <= 1.5 * e_svd + 1e-3


def test_error_feedback_telescopes():
    """EF invariant: sum_t compressed_t = sum_t g_t - residual_T."""
    rng = np.random.RandomState(2)
    compress = get_compressor("topk", k_frac=0.25)
    residual = ef.init({"w": jnp.zeros(16)})
    total_g = np.zeros(16)
    total_c = np.zeros(16)
    for t in range(5):
        g = {"w": jnp.asarray(rng.randn(16).astype(np.float32))}
        c, residual, _ = ef.apply(compress, g, residual)
        total_g += np.asarray(g["w"])
        total_c += np.asarray(c["w"])
    np.testing.assert_allclose(total_c + np.asarray(residual["w"]), total_g,
                               rtol=1e-4, atol=1e-5)


def test_get_compressor_none_identity():
    g = {"w": jnp.arange(4.0)}
    out, cost = get_compressor("none")(g)
    np.testing.assert_allclose(out["w"], g["w"])
    assert float(cost) == 4.0
