"""Hypothesis property tests for compression baselines. Skips wholesale
when the dev-only `hypothesis` package is absent (requirements-dev.txt);
deterministic coverage lives in test_compression.py."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import jax.numpy as jnp  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis.extra.numpy import arrays  # noqa: E402

from repro.compression import topk  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(arrays(np.float32, (32,), elements=st.floats(-5, 5, width=32)))
def test_topk_energy_dominates_random_subset(a):
    g = {"w": jnp.asarray(a)}
    out, _ = topk.compress(g, k_frac=0.25)
    kept = np.asarray(out["w"])
    k = int(np.count_nonzero(kept)) or 1
    rand_energy = np.sort(a ** 2)[:k].sum()
    assert kept.astype(np.float64) @ kept >= rand_energy * (1 - 1e-5) - 1e-6
