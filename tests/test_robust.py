"""Robust aggregation + attack injection + round-pipeline regression tests.

Five pillars:
  (a) the robust rules against pure-NumPy float64 oracles (smoothed
      Weiszfeld geometric median) and their algebraic contracts
      (client-permutation invariance, beta=0 trimmed mean == weighted
      mean, NaN phantom rows masked out),
  (b) ``aggregator="mean"`` is the pre-robustness streaming fold —
      bit-for-bit the default engine's round history on all three
      schedulers — and the collect path agrees with it to fp tolerance
      on both dense and sparse (scalar-round) payloads,
  (c) attack components are deterministic under a fixed seed (including
      the sparse scalar-round payload path) and leave clean runs
      untouched,
  (d) RoundPrefetcher regression: ``next()`` racing ``close()`` raises
      instead of deadlocking, the producer stops between rng draws, and
      a failed join is surfaced,
  (e) ``record_bench`` writes atomically and backs up (never discards)
      an unreadable trajectory.
"""
import json
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.fed import FLConfig, FLEngine
from repro.fed.attacks import fault_rng, make_attack, select_byzantine
from repro.fed.engine import RoundPrefetcher
from repro.fed.robust import (CoordinateMedian, GeometricMedian,
                              TrimmedMean, make_robust_rule)

# --------------------------------------------------------------- fixtures


@pytest.fixture(scope="module")
def fcn_setup():
    from repro.configs import get_config
    from repro.data.synthetic import mixture_classification
    from repro.models.smallnets import (apply_fcn, classifier_loss,
                                        init_fcn)
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(1200, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg,
                                           b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=6, **flkw):
    from repro.fed import partition_label_skew
    params, x, y, loss_fn = fcn_setup
    parts = partition_label_skew(y, K, 3, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             **flkw))


def run_rounds(fl, n=2, seed=0):
    rng = np.random.RandomState(seed)
    for _ in range(n):
        fl.run_round(rng)
    return fl


def _tree_equal(a, b):
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=k)


def _rand_stack(rng, K):
    return {"w": rng.randn(K, 5, 3).astype(np.float32),
            "b": rng.randn(K, 7).astype(np.float32)}


# ------------------------------------------- (a) rule oracles and algebra


def np_geometric_median(w, stacks, iters, eps):
    """Float64 smoothed-Weiszfeld reference on flattened vectors."""
    K = len(w)
    flat = np.concatenate(
        [np.where(w.reshape((-1,) + (1,) * (stacks[k].ndim - 1)) > 0,
                  stacks[k], 0.0).reshape(K, -1).astype(np.float64)
         for k in sorted(stacks)], axis=1)
    wf = w.astype(np.float64)
    z = wf @ flat / max(wf.sum(), 1e-20)
    for _ in range(iters):
        d = np.maximum(np.linalg.norm(flat - z, axis=1), eps)
        inv = wf / d
        z = inv @ flat / max(inv.sum(), 1e-20)
    return z


@pytest.mark.parametrize("seed", range(5))
def test_geometric_median_matches_numpy_oracle(seed):
    rng = np.random.RandomState(seed)
    K = 9
    w = rng.rand(K).astype(np.float32)
    w[seed % K] = 0.0                       # an unsampled client
    w /= w.sum()
    stacks = _rand_stack(rng, K)
    rule = GeometricMedian(iters=8, eps=1e-6)
    out = rule.reduce(w, {k: np.asarray(v) for k, v in stacks.items()})
    ref = np_geometric_median(w, stacks, iters=8, eps=1e-6)
    got = np.concatenate([np.asarray(out[k], np.float64).ravel()
                          for k in sorted(out)])
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("rule", [TrimmedMean(beta=0.15),
                                  CoordinateMedian(),
                                  GeometricMedian(iters=6)],
                         ids=["trimmed", "median", "gm"])
def test_rules_client_permutation_invariant(rule):
    rng = np.random.RandomState(7)
    K = 8
    w = rng.rand(K).astype(np.float32)
    w /= w.sum()
    stacks = _rand_stack(rng, K)
    perm = rng.permutation(K)
    out = rule.reduce(w, stacks)
    out_p = rule.reduce(w[perm], {k: v[perm] for k, v in stacks.items()})
    for k in out:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(out_p[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)


def test_trimmed_mean_beta0_is_weighted_mean():
    rng = np.random.RandomState(3)
    K = 7
    w = rng.rand(K).astype(np.float32)
    w /= w.sum()
    stacks = _rand_stack(rng, K)
    out = TrimmedMean(beta=0.0).reduce(w, stacks)
    for k in out:
        ref = np.tensordot(w.astype(np.float64),
                           stacks[k].astype(np.float64), axes=1)
        np.testing.assert_allclose(np.asarray(out[k]), ref,
                                   rtol=1e-5, atol=1e-6, err_msg=k)


def test_coordinate_median_uniform_weights_is_np_median():
    rng = np.random.RandomState(4)
    K = 9                                   # odd: the median is unique
    w = np.full(K, 1.0 / K, np.float32)
    stacks = _rand_stack(rng, K)
    out = CoordinateMedian().reduce(w, stacks)
    for k in out:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.median(stacks[k], axis=0),
                                   rtol=1e-6, atol=1e-7, err_msg=k)


@pytest.mark.parametrize("rule", [TrimmedMean(), CoordinateMedian(),
                                  GeometricMedian()],
                         ids=["trimmed", "median", "gm"])
def test_zero_weight_nan_rows_are_masked(rule):
    """Phantom pad clients may emit NaN at zero weight; every rule must
    produce the same answer as if the row did not exist."""
    rng = np.random.RandomState(5)
    K = 6
    w = rng.rand(K).astype(np.float32)
    w /= w.sum()
    stacks = _rand_stack(rng, K)
    w_pad = np.concatenate([w, np.zeros(2, np.float32)])
    stacks_pad = {k: np.concatenate(
        [v, np.full((2,) + v.shape[1:], np.nan, np.float32)])
        for k, v in stacks.items()}
    out = rule.reduce(w, stacks)
    out_pad = rule.reduce(w_pad, stacks_pad)
    for k in out:
        got = np.asarray(out_pad[k])
        assert np.isfinite(got).all(), k
        np.testing.assert_allclose(got, np.asarray(out[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)


def test_rule_kw_validation():
    with pytest.raises(ValueError, match="beta"):
        TrimmedMean(beta=0.5)
    with pytest.raises(ValueError, match="iters"):
        GeometricMedian(iters=0)
    with pytest.raises(ValueError, match="aggregator_kw"):
        make_robust_rule(FLConfig(aggregator="trimmed_mean",
                                  aggregator_kw={"nope": 1}))
    with pytest.raises(ValueError, match="unknown aggregator"):
        FLConfig(aggregator="nope")
    with pytest.raises(ValueError, match="attack_frac"):
        FLConfig(attack_frac=0.5)           # no attack named
    with pytest.raises(ValueError, match="unknown attack"):
        FLConfig(attack="nope", attack_frac=0.1)


# ----------------------- (b) engine seam: mean bit-for-bit, collect agrees


@pytest.mark.parametrize("flkw", [
    dict(scheduler="vmap"),
    dict(scheduler="chunked", chunk_size=4),
    dict(scheduler="sharded", chunk_size=4, lbg_variant="topk-sharded",
         lbg_kw={"k_frac": 0.25}),
], ids=["vmap", "chunked", "sharded"])
def test_mean_knob_is_bitforbit_default(fcn_setup, flkw):
    """aggregator="mean" (the default) routes to the pre-robustness
    streaming fold — identical params and history, not merely close."""
    base = dict(use_lbgm=True, delta_threshold=0.2, sample_frac=0.7)
    fl_a = run_rounds(make_engine(fcn_setup, **base, **flkw))
    fl_b = run_rounds(make_engine(fcn_setup, aggregator="mean",
                                  **base, **flkw))
    _tree_equal(fl_a.params, fl_b.params)
    assert fl_a.history == fl_b.history


@pytest.mark.parametrize("flkw", [
    dict(fused_kernels=False),
    dict(lbg_variant="topk", lbg_kw={"k_frac": 0.25}),
], ids=["dense-payload", "sparse-payload"])
def test_collect_beta0_trimmed_mean_agrees_with_streaming(fcn_setup,
                                                          flkw):
    """TrimmedMean(beta=0) through the collect path == the streaming
    weighted mean (fp tolerance: the fold orders the sums differently).
    The sparse case routes the (idx, val) scalar-round payloads through
    CollectSparseAggregator's densify — checking it reconstructs exactly
    the g_tilde the sparse streaming aggregator accumulates."""
    base = dict(scheduler="chunked", chunk_size=4, use_lbgm=True,
                delta_threshold=0.2)
    fl_a = run_rounds(make_engine(fcn_setup, **base, **flkw))
    fl_b = run_rounds(make_engine(fcn_setup, aggregator="trimmed_mean",
                                  aggregator_kw={"beta": 0.0},
                                  **base, **flkw))
    for k in fl_a.params:
        np.testing.assert_allclose(np.asarray(fl_a.params[k]),
                                   np.asarray(fl_b.params[k]),
                                   rtol=1e-4, atol=1e-5, err_msg=k)


# --------------------------------------- (c) attack/fault determinism


def test_select_byzantine_deterministic_and_sized():
    a = select_byzantine(20, 0.25, seed=3)
    b = select_byzantine(20, 0.25, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.sum() == 5
    assert select_byzantine(20, 0.25, seed=4).tolist() != a.tolist()
    assert select_byzantine(20, 0.0, seed=3).sum() == 0


@pytest.mark.parametrize("flkw", [
    dict(attack="gaussian", attack_kw={"sigma": 0.5}, fused_kernels=False),
    dict(attack="sign_flip", lbg_variant="topk", lbg_kw={"k_frac": 0.25},
         aggregator="geometric_median"),
    dict(attack="label_flip", dropout_frac=0.3, sample_frac=0.8),
], ids=["gaussian-dense", "signflip-sparse-gm", "labelflip-dropout"])
def test_attacked_runs_deterministic_under_seed(fcn_setup, flkw):
    """Same seed -> bit-identical attacked history (incl. the sparse
    scalar-round payload path and dropout fault injection); and the
    attack actually changes the run vs clean."""
    base = dict(scheduler="chunked", chunk_size=4, use_lbgm=True,
                delta_threshold=0.2, attack_frac=0.34)
    base.update(flkw)
    fl_a = run_rounds(make_engine(fcn_setup, **base), seed=1)
    fl_b = run_rounds(make_engine(fcn_setup, **base), seed=1)
    _tree_equal(fl_a.params, fl_b.params)
    assert fl_a.history == fl_b.history
    clean = dict(base, attack=None, attack_frac=0.0, dropout_frac=0.0)
    fl_c = run_rounds(make_engine(fcn_setup, **clean), seed=1)
    assert fl_a.history != fl_c.history


def test_label_flip_corrupts_only_byzantine_cohort(fcn_setup):
    fl = make_engine(fcn_setup, attack="label_flip", attack_frac=0.34,
                     use_lbgm=False)
    clean = make_engine(fcn_setup, use_lbgm=False)
    byz = fl._byz
    assert byz.sum() == 2
    for k in range(fl.cfg.num_clients):
        same = np.array_equal(np.asarray(fl.client_data[k]["y"]),
                              np.asarray(clean.client_data[k]["y"]))
        assert same == (byz[k] == 0), k


def test_attack_components_pure():
    """Payload attacks corrupt exactly the flagged clients (apply runs
    per client — scalar byz flag — under the scheduler's vmap/scan)."""
    rng = np.random.RandomState(0)
    asg = {"w": rng.randn(4, 3).astype(np.float32)}
    byz = np.array([0.0, 1.0, 0.0, 1.0], np.float32)
    flip = make_attack(FLConfig(attack="sign_flip", attack_frac=0.5))
    out = np.asarray(jax.vmap(lambda a, b: flip.apply(a, b, {}))(
        asg, byz)["w"])
    np.testing.assert_array_equal(out[[0, 2]], asg["w"][[0, 2]])
    np.testing.assert_array_equal(out[[1, 3]], -asg["w"][[1, 3]])
    rider = make_attack(FLConfig(attack="free_rider", attack_frac=0.5))
    out = np.asarray(jax.vmap(lambda a, b: rider.apply(a, b, {}))(
        asg, byz)["w"])
    assert (out[[1, 3]] == 0).all() and (out[[0, 2]] != 0).any()
    gauss = make_attack(FLConfig(attack="gaussian", attack_frac=0.5))
    ex = gauss.round_extras(fault_rng(0), 4)
    ex2 = gauss.round_extras(fault_rng(0), 4)
    assert ex.keys() == ex2.keys()
    for k in ex:
        np.testing.assert_array_equal(ex[k], ex2[k])


# ------------------------------------- (d) RoundPrefetcher regressions


class _BlockingEngine:
    """Fake engine whose batch draw parks until the prefetcher stops —
    the schedule that exposes the next()/close() race deterministically:
    the queue stays empty, so a consumer must wait, and a close() must
    still unblock it."""

    def __init__(self):
        self.pf = None                      # set after construction
        self.mask_draws = 0

    def _sample_batches(self, rng):
        # self.pf is assigned right after RoundPrefetcher() returns; the
        # producer thread can get here first, so spin until it lands
        while self.pf is None or not self.pf._stop.is_set():
            time.sleep(0.005)
        return rng.rand(2)

    def _sample_mask(self, rng):
        self.mask_draws += 1
        return rng.rand(2)


def test_prefetcher_next_unblocks_on_concurrent_close():
    """Regression: next()'s pre-checks were not atomic with its blocking
    q.get(), so a close() landing in between parked the consumer forever.
    The timeout-loop get must surface the close as a RuntimeError."""
    eng = _BlockingEngine()
    pf = RoundPrefetcher(eng, np.random.RandomState(0), depth=1)
    eng.pf = pf
    outcome = {}

    def consume():
        try:
            pf.next()
            outcome["r"] = "returned"
        except RuntimeError as e:
            outcome["r"] = str(e)

    t = threading.Thread(target=consume, daemon=True)
    t.start()
    time.sleep(0.2)                         # consumer is inside next()
    assert t.is_alive()                     # ...and blocked, queue empty
    pf.close()
    t.join(timeout=5)
    assert not t.is_alive(), "next() deadlocked against close()"
    assert "after close" in outcome["r"]


def test_prefetcher_producer_stops_between_rng_draws():
    """close() during the batch draw must not trigger the mask draw —
    the producer re-checks the stop flag between the two rng draws."""
    eng = _BlockingEngine()
    pf = RoundPrefetcher(eng, np.random.RandomState(0), depth=1)
    eng.pf = pf
    time.sleep(0.1)                         # producer parked in batch draw
    pf.close()
    assert not pf._thread.is_alive()
    assert eng.mask_draws == 0


def test_prefetcher_close_warns_if_thread_outlives_join():
    class _FastEngine:
        def _sample_batches(self, rng):
            return rng.rand(2)

        def _sample_mask(self, rng):
            return rng.rand(2)

    pf = RoundPrefetcher(_FastEngine(), np.random.RandomState(0), depth=1)

    class _WedgedThread:
        def join(self, timeout=None):
            pass

        def is_alive(self):
            return True

    real = pf._thread
    pf._thread = _WedgedThread()
    try:
        with pytest.warns(RuntimeWarning, match="did not exit"):
            pf.close()
    finally:
        pf._thread = real
        pf.close()


def test_prefetcher_matches_synchronous_stream(fcn_setup):
    """The prefetcher is still bit-identical to the synchronous path
    after the race fixes (the contract its docstring states)."""
    fl_a = make_engine(fcn_setup, use_lbgm=True, delta_threshold=0.2)
    fl_b = make_engine(fcn_setup, use_lbgm=True, delta_threshold=0.2)
    rng_a, rng_b = np.random.RandomState(2), np.random.RandomState(2)
    src = fl_a.prefetcher(rng_a)
    try:
        for _ in range(2):
            fl_a.run_round(src)
    finally:
        src.close()
    for _ in range(2):
        fl_b.run_round(rng_b)
    _tree_equal(fl_a.params, fl_b.params)
    assert fl_a.history == fl_b.history


# ------------------------------------------- (e) record_bench atomicity


def test_record_bench_appends_atomically(tmp_path, monkeypatch):
    from benchmarks.common import BENCH_PATH_ENV, record_bench
    path = tmp_path / "B.json"
    monkeypatch.setenv(BENCH_PATH_ENV, str(path))
    record_bench("a", 1.0, {"k": 1})
    record_bench("b", 2.0)
    entries = json.loads(path.read_text())
    assert [e["name"] for e in entries] == ["a", "b"]
    assert entries[0]["metadata"] == {"k": 1}
    # no temp droppings left behind
    assert [p.name for p in tmp_path.iterdir()] == ["B.json"]


def test_record_bench_backs_up_corrupt_trajectory(tmp_path, monkeypatch):
    from benchmarks.common import BENCH_PATH_ENV, record_bench
    path = tmp_path / "B.json"
    monkeypatch.setenv(BENCH_PATH_ENV, str(path))
    path.write_text("{truncated")
    with pytest.warns(RuntimeWarning, match="unreadable bench trajectory"):
        record_bench("fresh", 1.0)
    assert (tmp_path / "B.json.corrupt-0").read_text() == "{truncated"
    entries = json.loads(path.read_text())
    assert [e["name"] for e in entries] == ["fresh"]
    # a JSON object (not array) is also backed up, not silently reset
    path.write_text('{"not": "an array"}\n')
    with pytest.warns(RuntimeWarning, match="expected a JSON array"):
        record_bench("again", 2.0)
    assert os.path.exists(tmp_path / "B.json.corrupt-1")
