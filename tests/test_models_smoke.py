"""Per-architecture smoke tests (required deliverable f): instantiate a
REDUCED variant of each assigned family (2 layers, d_model<=512, <=4
experts) and run one forward + one train step on CPU, asserting output
shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, param_count
from repro.models.frontends import make_stub_embeds
from repro.models.transformer import forward, init_lm
from repro.train import trainer as tr


# the reduced smokes of these archs each cost 12-19 s on CPU (wide vocab /
# recurrent scan compiles); they run in CI's slow job, keeping the default
# verify loop fast while every arch stays covered
_HEAVY_SMOKE = {"whisper-base", "recurrentgemma-2b", "mixtral-8x22b",
                "llama4-maverick-400b-a17b", "rwkv6-3b"}


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_SMOKE else a
    for a in ASSIGNED_ARCHS])
def test_reduced_forward_and_train_step(arch, key):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe.num_experts:
        assert cfg.moe.num_experts <= 4
    params, axes = init_lm(key, cfg)
    assert set(axes) == set(params)

    B, T = 2, 64
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    extra = make_stub_embeds(key, cfg, B)
    logits, aux = jax.jit(lambda p, t, e: forward(p, cfg, t, e))(
        params, toks, extra)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    K = 2
    state, _ = tr.init_train_state(key, cfg, K)
    step = jax.jit(tr.make_train_step(cfg, K, lr=0.01))
    ktoks = jax.random.randint(key, (K, 2, T), 0, cfg.vocab_size)
    batch = {"tokens": ktoks, "labels": jnp.roll(ktoks, -1, axis=-1)}
    if extra is not None:
        batch["extra"] = jnp.broadcast_to(
            make_stub_embeds(key, cfg, 2)[None],
            (K, 2) + make_stub_embeds(key, cfg, 2).shape[1:])
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    moved = any(
        bool(jnp.any(state2["params"][k] != state["params"][k]))
        for k in state["params"])
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    assert param_count(cfg) > 0


def test_moe_expert_counts():
    assert get_config("llama4-maverick-400b-a17b").moe.num_experts == 128
    assert get_config("llama4-maverick-400b-a17b").moe.top_k == 1
    assert get_config("mixtral-8x22b").moe.num_experts == 8
    assert get_config("mixtral-8x22b").moe.top_k == 2


def test_paper_small_models(key):
    from repro.models.smallnets import (apply_cnn, apply_fcn, classifier_loss,
                                        init_cnn, init_fcn)
    x = jax.random.normal(key, (4, 28, 28, 1))
    y = jnp.asarray([0, 1, 2, 3])
    for init, apply, name in ((init_cnn, apply_cnn, "paper-cnn"),
                              (init_fcn, apply_fcn, "paper-fcn")):
        cfg = get_config(name)
        params, _ = init(key, cfg)
        loss, m = classifier_loss(apply, params, cfg, x, y)
        assert bool(jnp.isfinite(loss)) and 0.0 <= float(m["acc"]) <= 1.0
