"""Infrastructure: checkpointing, sharding rules, roofline parser, PCA,
optimizers, data, specs."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.pca import GradientSpaceTracker, cosine_matrix, n_pca
from repro.analysis.roofline import (RooflineReport, build_report,
                                     collective_bytes)
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import INPUT_SHAPES, get_config
from repro.data.synthetic import linear_regression, markov_lm
from repro.optim import adam_init, adam_update, sgd_init, sgd_update
from repro.optim.schedules import cosine, make_schedule
from repro.train import sharding as shd


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": np.arange(6.0).reshape(2, 3),
                        "nested": {"b": np.ones(4, np.float32)}},
             "step": np.asarray(7)}
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, state, {"arch": "test"})
    loaded, meta = load_checkpoint(path)
    assert meta["arch"] == "test"
    np.testing.assert_allclose(loaded["params"]["w"], state["params"]["w"])
    np.testing.assert_allclose(loaded["params"]["nested"]["b"],
                               state["params"]["nested"]["b"])
    assert int(loaded["step"]) == 7


# ------------------------------------------------------------- sharding

MESH = shd.abstract_mesh((16, 16), ("data", "model"))


def test_abstract_mesh_roundtrips():
    assert MESH.axis_names == ("data", "model")
    assert dict(MESH.shape) == {"data": 16, "model": 16}
    assert MESH.shape_tuple == (("data", 16), ("model", 16))


def test_param_pspec_rules():
    assert shd.param_pspec(("embed", "ff"), (512, 2048), "replicated",
                           MESH) == P(None, "model")
    assert shd.param_pspec(("embed", "ff"), (512, 2048), "fsdp",
                           MESH) == P("data", "model")
    # non-divisible dims stay unsharded
    assert shd.param_pspec(("embed", "ff"), (500, 2048), "fsdp",
                           MESH) == P(None, "model")
    assert shd.param_pspec(("vocab", "embed"), (32768, 512), "replicated",
                           MESH) == P("model", None)
    # one mesh axis never used twice
    spec = shd.param_pspec(("ff", "vocab"), (2048, 32768), "replicated", MESH)
    assert list(spec).count("model") == 1


def test_cache_pspec_prefers_kv_heads_then_head_dim():
    # kv=16 divisible => heads take the model axis
    s = shd.cache_pspec(("batch", "cache", "kv_heads", "head_dim"),
                        (128, 4096, 16, 128), MESH)
    assert s == P(("data",), None, "model", None)
    # kv=8 not divisible by 16 => head_dim takes it (distributed decode)
    s = shd.cache_pspec(("batch", "cache", "kv_heads", "head_dim"),
                        (128, 4096, 8, 128), MESH)
    assert s == P(("data",), None, None, "model")
    # batch=1 cannot shard
    s = shd.cache_pspec(("batch", "cache", "kv_heads", "head_dim"),
                        (1, 4096, 8, 128), MESH)
    assert s[0] is None


# ------------------------------------------------------------- roofline

HLO_SNIPPET = """
HloModule test
ENTRY %main {
  %ar = f32[1024,256]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag = bf16[512,128]{1,0} all-gather(%y), replica_groups=[16,16]<=[16,16]T(1,0)
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups={{0,1,2,3}}
  %cp = f32[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
}
"""


def test_collective_bytes_parser():
    out = collective_bytes(HLO_SNIPPET)
    n = 16
    ar = 1024 * 256 * 4
    assert out["all-reduce"] == pytest.approx(2 * ar * (n - 1) / n)
    ag = 512 * 128 * 2
    assert out["all-gather"] == pytest.approx(ag * (n - 1) / n)
    assert out["reduce-scatter"] == pytest.approx(64 * 4 * 3)
    assert out["collective-permute"] == pytest.approx(32 * 32 * 4)
    assert out["count"] == 4


def test_roofline_report_terms():
    rep = build_report("a", "s", "m", 256, {"flops": 197e12,
                                            "bytes accessed": 819e9},
                       HLO_SNIPPET, model_flops_global=197e12 * 256 * 0.5)
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.memory_s == pytest.approx(1.0)
    assert rep.dominant in ("compute", "memory")
    assert rep.useful_flops_ratio == pytest.approx(0.5)


# ------------------------------------------------------------- pca

def test_npca_detects_low_rank():
    rng = np.random.RandomState(0)
    basis = rng.randn(3, 64)
    grads = rng.randn(40, 3) @ basis  # rank 3 exactly
    assert n_pca(grads, 0.99) <= 3
    tr = GradientSpaceTracker()
    for g in grads[:10]:
        tr.add({"w": jnp.asarray(g)})
    s = tr.summary()
    assert s["n99_final"] <= 3 and s["epochs"] == 10
    hm_pgd, hm_self = tr.heatmaps()
    assert hm_self.shape == (10, 10)
    np.testing.assert_allclose(np.diag(hm_self), 1.0, atol=1e-5)


# ------------------------------------------------------------- optim/data

def test_sgd_momentum_and_adam(key):
    params = {"w": jnp.ones(4)}
    grads = {"w": jnp.full(4, 0.5)}
    p1, _ = sgd_update(params, grads, sgd_init(params), lr=0.1)
    np.testing.assert_allclose(p1["w"], 0.95)
    st = sgd_init(params, momentum=0.9)
    p2, st = sgd_update(params, grads, st, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(p2["w"], 0.95)
    ast = adam_init(params)
    p3, ast = adam_update(params, grads, ast, lr=0.1)
    assert float(p3["w"][0]) < 1.0


def test_schedules():
    f = cosine(1.0, 100, warmup=10)
    assert float(f(0)) == 0.0
    assert float(f(10)) == pytest.approx(1.0)
    assert float(f(100)) == pytest.approx(0.0, abs=1e-6)
    g = make_schedule("corollary1", 0.0, 100, tau=4)
    assert float(g(0)) == pytest.approx(1 / (4 * 100) ** 0.5)


def test_markov_lm_learnable_structure():
    x, y = markov_lm(4, 32, vocab=64, seed=0)
    assert x.shape == (4, 32) and np.all(x[:, 1:] == y[:, :-1])


# ------------------------------------------------------------- specs

def test_abstract_specs_no_allocation():
    from repro.launch import specs as sp
    cfg = get_config("qwen3-1.7b")
    sds, axes = sp.abstract_params(cfg)
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in sds.values())
    assert set(axes) == set(sds)
    st, sa = sp.abstract_decode_state(cfg, 8, 1024)
    assert isinstance(st["pos"], jax.ShapeDtypeStruct)
    b = sp.train_batch_specs(cfg, INPUT_SHAPES["train_4k"], 16)
    assert b["tokens"].shape == (16, 16, 4096)
