"""Unit tests for the checkpoint codec (``repro.checkpoint.ckpt``).

Covers the flat-dict <-> nested-tree round-trip (dicts, lists, scalars,
mixed dtypes), metadata transport, the atomic-write guarantee (a crash
mid-save must leave the previous checkpoint intact and no temp litter),
and the ``_flatten`` key regression: a leaf key ending in ``:`` used to
be corrupted by ``rstrip``-based separator stripping.
"""
import os

import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.checkpoint.ckpt import _flatten, _unflatten


def _assert_tree_equal(a, b, path=""):
    # scalars legitimately come back as 0-d ndarrays (np.savez round-trip)
    if isinstance(a, dict) or isinstance(b, dict):
        assert type(a) is type(b), (path, type(a), type(b))
    if isinstance(a, dict):
        assert sorted(a) == sorted(b), path
        for k in a:
            _assert_tree_equal(a[k], b[k], f"{path}/{k}")
    elif isinstance(a, list):
        assert len(a) == len(b), path
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_tree_equal(x, y, f"{path}#{i}")
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=path)


def test_round_trip_nested(tmp_path):
    state = {
        "params": {"w1": np.arange(6, dtype=np.float32).reshape(2, 3),
                   "b1": np.zeros(3, np.float64)},
        "banks": [{"idx": np.array([[0, 2]], np.int32),
                   "val": np.array([[1.5, -2.0]], np.float32)},
                  {"idx": np.array([[1]], np.int32),
                   "val": np.array([[0.25]], np.float32)}],
        "round": np.int64(7),
    }
    p = tmp_path / "ck.npz"
    save_checkpoint(str(p), state, {"version": 1, "note": "x"})
    loaded, meta = load_checkpoint(str(p))
    _assert_tree_equal(state, loaded)
    assert meta == {"version": 1, "note": "x"}
    # dtypes survive exactly
    assert loaded["params"]["b1"].dtype == np.float64
    assert loaded["banks"][0]["idx"].dtype == np.int32


def test_colon_suffixed_key_regression(tmp_path):
    # ``a:`` flattened to ``a:`` + separator ``::`` = ``a:::``; stripping
    # with rstrip(':') ate every trailing colon and collided the key with
    # plain ``a`` — removesuffix must peel exactly one separator.
    # (Interior dict keys containing ':' remain out of contract: the
    # flat-key split on '::' cannot disambiguate them.)
    state = {"a:": np.float32(1.0), "a": np.float32(2.0),
             "nested": {"w:": np.float32(3.0)}}
    flat = _flatten(state)
    assert sorted(flat) == ["a", "a:", "nested::w:"]
    _assert_tree_equal(state, _unflatten(flat))
    p = tmp_path / "ck.npz"
    save_checkpoint(str(p), state, {})
    loaded, _ = load_checkpoint(str(p))
    _assert_tree_equal(state, loaded)
    assert float(loaded["a:"]) == 1.0 and float(loaded["a"]) == 2.0
    assert float(loaded["nested"]["w:"]) == 3.0


def test_atomic_write_crash_safety(tmp_path, monkeypatch):
    p = tmp_path / "ck.npz"
    save_checkpoint(str(p), {"x": np.float32(1.0)}, {"round": 1})

    def boom(*a, **k):
        raise RuntimeError("disk full")

    # crash inside the tmp-file write: the published checkpoint must
    # still load, and the tmp file must not leak
    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(RuntimeError):
        save_checkpoint(str(p), {"x": np.float32(2.0)}, {"round": 2})
    monkeypatch.undo()
    loaded, meta = load_checkpoint(str(p))
    assert float(loaded["x"]) == 1.0 and meta["round"] == 1
    assert [f for f in os.listdir(tmp_path) if f != "ck.npz"] == []


def test_crash_between_write_and_replace(tmp_path, monkeypatch):
    p = tmp_path / "ck.npz"
    save_checkpoint(str(p), {"x": np.float32(1.0)}, {})
    real_replace = os.replace

    def boom(*a, **k):
        raise OSError("power loss")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError):
        save_checkpoint(str(p), {"x": np.float32(2.0)}, {})
    monkeypatch.setattr(os, "replace", real_replace)
    loaded, _ = load_checkpoint(str(p))
    assert float(loaded["x"]) == 1.0
    assert [f for f in os.listdir(tmp_path) if f != "ck.npz"] == []


def test_empty_containers_flatten_to_nothing():
    # empty dicts/lists produce no keys — consumers restore them with
    # .get(...) defaults, pinned here so the engine's guards stay honest
    assert _flatten({"a": {}, "b": [], "c": np.float32(1.0)}).keys() == {"c"}
