"""Hypothesis property tests for the LBGM core. Skips wholesale when the
dev-only `hypothesis` package is absent (requirements-dev.txt); the
deterministic coverage lives in test_lbgm.py and test_engine.py."""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import jax.numpy as jnp  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis.extra.numpy import arrays  # noqa: E402

from repro.core.lbgm import lbgm_stats  # noqa: E402
from repro.core.tree_math import tree_sq_norm  # noqa: E402

FLOATS = st.floats(-10, 10, allow_nan=False, width=32)


def vecs(n=16):
    return arrays(np.float32, (n,), elements=FLOATS)


def as_tree(a):
    return {"w": jnp.asarray(a[: len(a) // 2]),
            "b": jnp.asarray(a[len(a) // 2:])}


@settings(max_examples=50, deadline=None)
@given(vecs(), vecs())
def test_sin2_in_unit_interval(a, b):
    sin2, _, _ = lbgm_stats(as_tree(a), as_tree(b))
    assert -1e-5 <= float(sin2) <= 1.0 + 1e-5


@settings(max_examples=50, deadline=None)
@given(vecs(), vecs(), st.floats(0.0625, 16, width=32))
def test_rho_scale_equivariance(a, b, c):
    """Scaling the gradient scales the LBC; sin^2 is scale invariant."""
    hypothesis.assume(np.linalg.norm(a) > 1e-2 and np.linalg.norm(b) > 1e-2)
    g, lbg = as_tree(a), as_tree(b)
    g2 = jax.tree.map(lambda x: c * x, g)
    s1, r1, _ = lbgm_stats(g, lbg)
    s2, r2, _ = lbgm_stats(g2, lbg)
    np.testing.assert_allclose(float(s1), float(s2), atol=1e-4)
    np.testing.assert_allclose(float(r2), c * float(r1),
                               rtol=2e-3, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(vecs(), vecs(), st.floats(0.0, 1.0, width=32))
def test_reconstruction_error_bounded_by_lbp(a, b, delta):
    """Theorem-1 geometry: ||g - rho*lbg||^2 = ||g||^2 sin^2(alpha)."""
    hypothesis.assume(np.linalg.norm(a) > 1e-2 and np.linalg.norm(b) > 1e-2)
    g, lbg = as_tree(a), as_tree(b)
    sin2, rho, gg = lbgm_stats(g, lbg)
    approx = jax.tree.map(lambda x: rho * x, lbg)
    err = tree_sq_norm(jax.tree.map(lambda x, y: x - y, g, approx))
    np.testing.assert_allclose(float(err), float(gg * sin2),
                               rtol=1e-3, atol=1e-3)
