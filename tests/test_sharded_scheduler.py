"""Multi-device "sharded" client scheduler + "topk-sharded" LBG store.

Acceptance (ISSUE 3 tentpole):
  * on a 1-device mesh the sharded scheduler reproduces the chunked
    scheduler's round history bit-for-bit (same sequential accumulation,
    same chunk/pad layout);
  * on a multi-device mesh (forced host devices, subprocess) it matches
    within fp32 tolerance with IDENTICAL uplink accounting;
  * an ``ExperimentSpec`` carrying ``FLConfig.mesh`` round-trips losslessly
    through JSON and runs via ``python -m repro.fed.run``.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import (FLConfig, FLEngine, ShardedTopKLBGStore, TopKLBGStore,
                       make_lbg_store, partition_iid)
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def fcn_setup():
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(900, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=10, **flkw):
    params, x, y, loss_fn = fcn_setup
    parts = partition_iid(len(y), K, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             **flkw))


def _assert_identical_run(fl_a, fl_b, rounds=3):
    ha = fl_a.run(rounds)
    hb = fl_b.run(rounds)
    for k in fl_a.params:
        np.testing.assert_array_equal(np.asarray(fl_a.params[k]),
                                      np.asarray(fl_b.params[k]), err_msg=k)
    assert ha == hb


# ------------------------------------------------------------ unit pieces


def test_pick_sharded_chunk_layout():
    from repro.fed.engine import pick_chunk, pick_sharded_chunk
    # 1 device: exactly the chunked policy (shared layout -> bit-for-bit)
    for K, c in ((20, 16), (100, 20), (7, 4), (1, 16)):
        assert pick_sharded_chunk(K, c, 1) == pick_chunk(K, c)
    # blocks always split evenly over the mesh
    assert pick_sharded_chunk(16, 8, 4) == 8      # exact divisor kept
    assert pick_sharded_chunk(24, 10, 4) == 8     # largest multiple of 4
    assert pick_sharded_chunk(7, 4, 4) == 4       # prime K: pad instead
    assert pick_sharded_chunk(10, 2, 4) == 4      # chunk rounds up to mesh
    # the block caps at K (rounded to the grid): a small cohort under a
    # large default chunk_size must not produce phantom-dominated chunks
    assert pick_sharded_chunk(4, 16, 4) == 4
    assert pick_sharded_chunk(6, 16, 4) == 4      # pad 2, not pad 10
    assert pick_sharded_chunk(8, 32, 4) == 8
    for K, c, d in ((16, 8, 4), (24, 10, 4), (7, 4, 4), (512, 8, 8),
                    (4, 16, 4), (6, 16, 4)):
        assert pick_sharded_chunk(K, c, d) % d == 0


def test_mesh_knob_validation():
    with pytest.raises(ValueError, match="mesh"):
        FLConfig(mesh=0)
    with pytest.raises(ValueError, match="mesh"):
        FLConfig(mesh=-2)
    assert FLConfig(mesh=None).mesh is None
    assert FLConfig(scheduler="sharded", mesh=1).mesh == 1


def test_mesh_too_large_fails_at_build(fcn_setup):
    n = len(jax.devices())
    with pytest.raises(RuntimeError, match="device"):
        make_engine(fcn_setup, K=4, scheduler="sharded", mesh=n + 1)


def test_sharded_store_registered_and_interchangeable():
    cfg = FLConfig(lbg_variant="topk-sharded", lbg_kw={"k_frac": 0.25})
    store = make_lbg_store(cfg)
    assert isinstance(store, ShardedTopKLBGStore)
    # same decision core as TopKLBGStore: bit-identical client step
    plain = TopKLBGStore(cfg.delta_threshold, k_frac=0.25)
    params = {"w": jnp.zeros((30, 8)), "b": jnp.zeros(12)}
    bank = store.init(params, num_clients=4)
    assert jax.tree.structure(bank) == jax.tree.structure(
        plain.init(params, num_clients=4))
    rng = np.random.RandomState(0)
    g = {k: jnp.asarray(rng.randn(*v.shape).astype(np.float32))
         for k, v in params.items()}
    lbg_k = jax.tree.map(lambda x: x[0], bank)
    gt_a, nl_a, st_a = store.client_step(g, lbg_k)
    gt_b, nl_b, st_b = plain.client_step(g, lbg_k)
    for a, b in zip(jax.tree.leaves((gt_a, nl_a, tuple(st_a))),
                    jax.tree.leaves((gt_b, nl_b, tuple(st_b)))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # cost model passes through unchanged
    assert float(store.full_round_cost(jnp.asarray(0.0), st_a)) \
        == float(plain.full_round_cost(jnp.asarray(0.0), st_b))


# ---------------------------------------- 1-device bit-for-bit equivalence


def test_sharded_equals_chunked_1device_bitforbit(fcn_setup):
    """Acceptance: same seed, 1-device mesh -> identical history/params."""
    kw = dict(use_lbgm=True, delta_threshold=0.2, chunk_size=5)
    fl_c = make_engine(fcn_setup, K=10, scheduler="chunked", **kw)
    fl_s = make_engine(fcn_setup, K=10, scheduler="sharded", mesh=1, **kw)
    assert (fl_s._chunk, fl_s._pad) == (fl_c._chunk, fl_c._pad)
    _assert_identical_run(fl_c, fl_s, rounds=3)


def test_sharded_equals_chunked_1device_topk_store(fcn_setup):
    """chunked+topk vs sharded+topk-sharded: stores are interchangeable,
    so the histories stay bit-for-bit equal."""
    kw = dict(use_lbgm=True, delta_threshold=0.5, chunk_size=3,
              lbg_kw={"k_frac": 0.25})
    fl_c = make_engine(fcn_setup, K=6, scheduler="chunked",
                       lbg_variant="topk", **kw)
    fl_s = make_engine(fcn_setup, K=6, scheduler="sharded", mesh=1,
                       lbg_variant="topk-sharded", **kw)
    _assert_identical_run(fl_c, fl_s, rounds=3)


def test_sharded_equals_chunked_padding_sampling_ef(fcn_setup):
    """Prime K (padded tail) + Algorithm-3 sampling + compressor/EF."""
    kw = dict(use_lbgm=True, delta_threshold=0.3, chunk_size=4,
              compressor="topk", compressor_kw={"k_frac": 0.1},
              error_feedback=True, sample_frac=0.6)
    fl_c = make_engine(fcn_setup, K=7, scheduler="chunked", **kw)
    fl_s = make_engine(fcn_setup, K=7, scheduler="sharded", mesh=1, **kw)
    assert fl_s._chunk == 4 and fl_s._pad == 1
    _assert_identical_run(fl_c, fl_s, rounds=4)


def test_sharded_banks_layout(fcn_setup):
    """Banks are stored (n_chunks, chunk, ...) under the sharded scheduler
    so the chunk's client axis can shard over the mesh."""
    fl = make_engine(fcn_setup, K=10, scheduler="sharded", mesh=1,
                     chunk_size=5, use_lbgm=True, delta_threshold=0.2,
                     error_feedback=True, compressor="topk",
                     compressor_kw={"k_frac": 0.25})
    for leaf in jax.tree.leaves(fl.lbg):
        assert leaf.shape[:2] == (2, 5)
    for leaf in jax.tree.leaves(fl.residual):
        assert leaf.shape[:2] == (2, 5)


# ------------------------------------------------- spec / CLI integration


def test_spec_with_mesh_roundtrips_and_runs(tmp_path):
    from repro.fed import ExperimentSpec
    from repro.fed.run import main

    spec = ExperimentSpec.from_dict({
        "name": "sharded-smoke",
        "data": {"name": "mixture", "kw": {"n": 160, "n_eval": 40}},
        "fl": {"num_clients": 4, "batch_size": 8, "scheduler": "sharded",
               "chunk_size": 2, "mesh": 1},
        "rounds": 2,
        "eval": {"every": 0, "final": True},
    })
    # lossless JSON round-trip, mesh included
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec and again.fl.mesh == 1
    assert json.loads(spec.to_json())["fl"]["mesh"] == 1
    # runs through the CLI entry point (in-process)
    path = tmp_path / "spec.json"
    spec.save(str(path))
    out = tmp_path / "result.json"
    assert main(["--spec", str(path), "--out", str(out)]) == 0
    result = json.loads(out.read_text())
    assert result["spec"]["fl"]["mesh"] == 1
    assert len(result["records"]) == 2
    assert np.isfinite(result["records"][-1]["loss"])


# ------------------------------------------------- multi-device (forced)

MULTI_DEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import numpy as np
from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_iid
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

assert len(jax.devices()) == 4
cfg = get_config("paper-fcn")
params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
x, y = mixture_classification(600, 10, seed=0)
loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
parts = partition_iid(len(y), 12, seed=0)
data = [{"x": x[p], "y": y[p]} for p in parts]

def eng(**kw):
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=12, tau=2, lr=0.05, batch_size=16,
                             use_lbgm=True, delta_threshold=0.2,
                             sample_frac=0.8, compressor="topk",
                             compressor_kw={"k_frac": 0.25},
                             error_feedback=True, chunk_size=4, **kw))

fc = eng(scheduler="chunked", lbg_variant="topk", lbg_kw={"k_frac": 0.25})
fs = eng(scheduler="sharded", mesh=4, lbg_variant="topk-sharded",
         lbg_kw={"k_frac": 0.25})
assert fs.sched.n_dev == 4
# the bank is physically sharded along the chunk's client axis
shardings = {str(l.sharding.spec) for l in jax.tree.leaves(fs.lbg)}
assert shardings == {"PartitionSpec(None, 'clients')"}, shardings
hc = fc.run(3)
hs = fs.run(3)
# round 1 enters with bit-identical params, so uplink accounting is EXACT
# (the per-client decision is device-local); later rounds run on params
# that have drifted within fp32 tolerance, where a client whose sin2 sits
# right at delta could legitimately flip its accept/recycle branch on
# another platform/jax version — assert those within one decision margin
assert hc[0]["uplink_floats"] == hs[0]["uplink_floats"], (hc[0], hs[0])
assert hc[0]["frac_scalar"] == hs[0]["frac_scalar"], (hc[0], hs[0])
M = sum(int(v.size) for v in params.values())
flip = 1.5 * 0.25 * M  # one client's full-round topk cost
for a, b in zip(hc, hs):
    np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5, atol=1e-7)
    assert abs(a["uplink_floats"] - b["uplink_floats"]) <= 2 * flip, (a, b)
for k in fc.params:
    np.testing.assert_allclose(np.asarray(fc.params[k]),
                               np.asarray(fs.params[k]),
                               rtol=1e-5, atol=1e-6)
print(json.dumps({"ok": True}))
"""


@pytest.mark.slow
def test_sharded_multi_device_matches_chunked():
    """Acceptance: 4-device mesh matches chunked within fp32 tolerance with
    identical uplink accounting (subprocess: forced host device count)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", MULTI_DEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
