"""Deterministic unit tests for the LBGM core (paper Algorithm 1).

Randomized hypothesis property tests live in test_lbgm_properties.py so
this module stays collectible when the dev-only `hypothesis` package is
absent (see requirements-dev.txt).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lbgm import (corollary1_threshold, init_topk_lbg, leaf_topk,
                             lbgm_client_step, lbgm_stats,
                             lbgm_topk_client_step, topk_count)


# ------------------------------------------------------------ exact algebra

def test_parallel_gradient_exact_reconstruction():
    """sin^2 = 0 when g = c*lbg => reconstruction rho*lbg == g exactly."""
    lbg = {"w": jnp.arange(1.0, 9.0), "b": jnp.ones((4,))}
    g = jax.tree.map(lambda x: 2.5 * x, lbg)
    sin2, rho, _ = lbgm_stats(g, lbg)
    assert sin2 < 1e-6
    assert abs(rho - 2.5) < 1e-6
    gt, new_lbg, stats = lbgm_client_step(g, lbg, delta_threshold=0.01)
    assert bool(stats.sent_scalar)
    for k in g:
        np.testing.assert_allclose(gt[k], g[k], rtol=1e-6)
        np.testing.assert_allclose(new_lbg[k], lbg[k])  # LBG unchanged


def test_orthogonal_gradient_full_round():
    g = {"w": jnp.array([1.0, 0.0])}
    lbg = {"w": jnp.array([0.0, 1.0])}
    sin2, rho, _ = lbgm_stats(g, lbg)
    assert abs(sin2 - 1.0) < 1e-6 and abs(rho) < 1e-6
    gt, new_lbg, stats = lbgm_client_step(g, lbg, 0.5)
    assert not bool(stats.sent_scalar)
    np.testing.assert_allclose(gt["w"], g["w"])       # full gradient sent
    np.testing.assert_allclose(new_lbg["w"], g["w"])  # LBG refreshed


def test_zero_lbg_forces_full_round():
    """Degenerate LBG (round 0) must force a full transmission."""
    g = {"w": jnp.array([1.0, 2.0])}
    lbg = {"w": jnp.zeros(2)}
    sin2, _, _ = lbgm_stats(g, lbg)
    assert sin2 == 1.0
    _, new_lbg, stats = lbgm_client_step(g, lbg, 0.99)
    assert not bool(stats.sent_scalar)
    np.testing.assert_allclose(new_lbg["w"], g["w"])


def test_delta_one_always_scalar_after_init():
    g = {"w": jnp.array([3.0, -1.0])}
    lbg = {"w": jnp.array([1.0, 1.0])}
    _, _, stats = lbgm_client_step(g, lbg, delta_threshold=1.0)
    assert bool(stats.sent_scalar)


def test_uplink_accounting():
    g = {"w": jnp.ones((10,)), "b": jnp.ones((6,))}
    lbg = jax.tree.map(jnp.zeros_like, g)
    _, lbg, s0 = lbgm_client_step(g, lbg, 1.0)
    assert float(s0.uplink_floats) == 16.0          # full round: M floats
    _, _, s1 = lbgm_client_step(g, lbg, 1.0)
    assert float(s1.uplink_floats) == 1.0           # scalar round


# ------------------------------------------------------------ topk variant

def test_leaf_topk_selects_largest():
    g = jnp.asarray(np.random.RandomState(0).randn(1000).astype(np.float32))
    sp = leaf_topk(g, 0.01)              # single block: global top-k
    val = np.asarray(sp["val"]).reshape(-1)
    k = val.size
    thresh = np.sort(np.abs(np.asarray(g)))[-k]
    assert np.all(np.abs(val) >= thresh - 1e-6)
    np.testing.assert_allclose(np.asarray(g)[np.asarray(sp["idx"]).reshape(-1)],
                               val)


def test_blockwise_topk_large_leaf_roundtrip():
    from repro.core.lbgm import leaf_scatter, leaf_sparse_gather
    n = 200_000  # > BLOCK => blockwise path, nb rounded to multiple of 16
    g = jnp.asarray(np.random.RandomState(1).randn(n).astype(np.float32))
    sp = leaf_topk(g, 0.01)
    nb, kb = sp["idx"].shape
    assert nb % 16 == 0
    assert topk_count(n, 0.01) == nb * kb
    dense = np.asarray(leaf_scatter(sp, (n,), n, 0.01))
    nz = np.nonzero(dense)[0]
    np.testing.assert_allclose(dense[nz], np.asarray(g)[nz])
    back = leaf_sparse_gather(g, sp, 0.01)
    np.testing.assert_allclose(np.asarray(back), np.asarray(sp["val"]))


def test_topk_lbgm_parallel_scalar_round():
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(64, 8).astype(np.float32))}
    lbg = init_topk_lbg(g, k_frac=0.25)
    # round 1: zero LBG -> full round, LBG refreshed with topk(g)
    gt, lbg, s = lbgm_topk_client_step(g, lbg, 0.2, 0.25)
    assert not bool(s.sent_scalar)
    # round 2: same gradient scaled -> the sparse LBG is parallel to
    # the *sparsified* g, and the dense/sparse cos^2 is high
    g2 = jax.tree.map(lambda x: 1.7 * x, g)
    gt2, lbg2, s2 = lbgm_topk_client_step(g2, lbg, 0.7, 0.25)
    assert bool(s2.sent_scalar)
    assert float(s2.uplink_floats) == 1.0
    # reconstruction = rho * dense(lbg)
    from repro.core.lbgm import leaf_scatter
    dense_lbg = np.asarray(leaf_scatter(lbg["w"], (64 * 8,), 64 * 8, 0.25))
    np.testing.assert_allclose(
        np.asarray(gt2["w"]).reshape(-1),
        float(s2.rho) * dense_lbg, rtol=1e-4, atol=1e-5)


def test_corollary1_threshold_monotone():
    t1 = corollary1_threshold(jnp.asarray(1.0), tau=2, total_rounds=100)
    t2 = corollary1_threshold(jnp.asarray(100.0), tau=2, total_rounds=100)
    assert float(t1) > float(t2)  # larger gradients => tighter threshold
    assert 0.0 <= float(t2) <= 1.0
