"""Out-of-core ``"topk-host"`` LBG store: host-resident client banks
streamed chunk-wise to the device.

Acceptance (ISSUE 10 tentpole):
  * ``lbg_variant="topk-host"`` reproduces ``"topk"`` round histories,
    final params AND final banks *bit-for-bit* on the chunked scheduler
    (the chunk computation is op-for-op the chunked scan body), composing
    with device sampling, codecs and hierarchical tiers;
  * per-round device bank bytes are O(chunk_size) — independent of
    ``num_clients`` (compiled-envelope + exact chunk-bytes assertions,
    and a slow-marked K=100,000 toy round);
  * incompatible configs fail at construction with actionable errors.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_label_skew
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn


@pytest.fixture(scope="module")
def fcn_setup():
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(1200, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=8, **flkw):
    params, x, y, loss_fn = fcn_setup
    flkw.setdefault("use_lbgm", True)
    flkw.setdefault("lbg_variant", "topk")
    flkw.setdefault("lbg_kw", {"k_frac": 0.1})
    flkw.setdefault("delta_threshold", 0.5)
    flkw.setdefault("scheduler", "chunked")
    parts = partition_label_skew(y, K, 3, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             chunk_size=4, **flkw))


def run_rounds(fl, n=3, seed=0):
    rng = np.random.RandomState(seed)
    for _ in range(n):
        fl.run_round(rng)
    return fl


def assert_same_run(fl_a, fl_b, banks=True):
    assert len(fl_a.history) == len(fl_b.history)
    for ra, rb in zip(fl_a.history, fl_b.history):
        assert ra.keys() == rb.keys()
        for k in ra:
            assert ra[k] == rb[k], (k, ra[k], rb[k])
    for k in fl_a.params:
        np.testing.assert_array_equal(np.asarray(fl_a.params[k]),
                                      np.asarray(fl_b.params[k]), err_msg=k)
    if banks:
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            fl_a.lbg, fl_b.lbg)


# --------------------------------------------------------- bit-for-bit

@pytest.mark.parametrize("extra", [
    {},
    {"sample_frac": 0.5},
    {"tiers": [4, 2]},
    {"codec": "int8"},
], ids=["plain", "sampled", "tiered", "codec"])
def test_host_store_bit_for_bit_vs_topk(fcn_setup, extra):
    dev = run_rounds(make_engine(fcn_setup, **extra))
    host = run_rounds(make_engine(fcn_setup, lbg_variant="topk-host",
                                  **extra))
    assert host._host_bank
    # banks live on the host as numpy, not on device
    assert all(isinstance(v, np.ndarray)
               for v in jax.tree.leaves(host.lbg))
    assert_same_run(dev, host)


def test_host_store_engine_run_prefetch(fcn_setup):
    # the engine-owned prefetcher path (batch+mask sampled on the
    # producer thread) composes with the bank streamer thread
    dev = make_engine(fcn_setup)
    host = make_engine(fcn_setup, lbg_variant="topk-host")
    ha = dev.run(3)
    hb = host.run(3)
    assert ha == hb
    assert_same_run(dev, host)


# ------------------------------------------------------- config surface

def test_host_store_config_rejections(fcn_setup):
    with pytest.raises(ValueError, match="topk-host"):
        FLConfig(num_clients=8, use_lbgm=True, lbg_variant="topk-host",
                 scheduler="vmap")
    with pytest.raises(ValueError, match="topk-host"):
        FLConfig(num_clients=8, use_lbgm=True, lbg_variant="topk-host",
                 scheduler="chunked", error_feedback=True)
    with pytest.raises(ValueError, match="topk-host"):
        FLConfig(num_clients=8, use_lbgm=True, lbg_variant="topk-host",
                 scheduler="chunked", compressor="topk")  # EF default on
    with pytest.raises(ValueError, match="topk-host"):
        FLConfig(num_clients=8, use_lbgm=True, lbg_variant="topk-host",
                 scheduler="chunked", fused_kernels=False)
    with pytest.raises(ValueError):
        FLConfig(num_clients=8, use_lbgm=True, lbg_variant="topk-host",
                 scheduler="buffered")
    # collect-mode aggregators need the full payload stack on device —
    # rejected at engine build, pointing at the streaming mean
    with pytest.raises(ValueError, match="mean"):
        make_engine(fcn_setup, lbg_variant="topk-host",
                    aggregator="median")


# ------------------------------------------------- device-memory envelope

def _chunk_args(fl):
    """ShapeDtypeStructs of one host-chunk call, from live engine state."""
    sds = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
    params = jax.tree.map(sds, fl.params)
    acc = jax.eval_shape(fl.agg.init, params)
    lbg_c = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((fl._chunk,) + a.shape[1:],
                                       a.dtype), fl.lbg)
    batch = fl._sample_batches(np.random.RandomState(99))
    b_c = {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
           for k, v in batch.items()}
    w_c = jax.ShapeDtypeStruct((fl._chunk,), jnp.float32)
    return params, acc, lbg_c, {}, b_c, w_c, w_c


def test_device_bank_bytes_independent_of_K(fcn_setup):
    small = make_engine(fcn_setup, K=8, lbg_variant="topk-host")
    big = make_engine(fcn_setup, K=32, lbg_variant="topk-host")
    assert small.host_chunk_device_bytes() == big.host_chunk_device_bytes()
    # the compiled chunk computation itself is K-free: identical input
    # shapes, and (when the backend reports it) identical memory envelope
    args_s, args_b = _chunk_args(small), _chunk_args(big)
    shapes = lambda args: [(a.shape, str(a.dtype))
                           for a in jax.tree.leaves(args)]
    assert shapes(args_s) == shapes(args_b)
    ma_s = small._chunk_fn.lower(*args_s).compile().memory_analysis()
    ma_b = big._chunk_fn.lower(*args_b).compile().memory_analysis()
    if ma_s is not None and ma_b is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes"):
            assert getattr(ma_s, attr) == getattr(ma_b, attr), attr


# ------------------------------------------------------ 100k-client round

def _tiny_fl(K, chunk=512):
    d = 8
    rng = np.random.RandomState(0)
    params = {"w": jnp.asarray(rng.randn(d).astype(np.float32) * 0.1)}

    def loss_fn(p, b):
        err = b["x"] @ p["w"] - b["y"]
        return jnp.mean(err * err), {}

    x = rng.randn(K * 4, d).astype(np.float32)
    y = (x @ np.arange(d, dtype=np.float32) / d).astype(np.float32)
    data = [{"x": x[4 * k: 4 * k + 4], "y": y[4 * k: 4 * k + 4]}
            for k in range(K)]
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=1, lr=0.1, batch_size=4,
                             chunk_size=chunk, scheduler="chunked",
                             use_lbgm=True, lbg_variant="topk-host",
                             lbg_kw={"k_frac": 0.25},
                             delta_threshold=0.5))


@pytest.mark.slow
def test_100k_client_round_fixed_device_memory():
    # 102400 = 200 * 512: keeps the resolved chunk identical to the
    # K=1024 reference (pick_chunk prefers exact divisors — 100000 would
    # resolve to chunk 500 and shift every shape by 12 rows)
    small = _tiny_fl(1024)
    big = _tiny_fl(102_400)
    assert small._chunk == big._chunk == 512
    # the acceptance claim: per-round device bank bytes do not grow with
    # the cohort — same streamed-chunk footprint at K=1k and K=100k
    assert small.host_chunk_device_bytes() == big.host_chunk_device_bytes()
    args_s, args_b = _chunk_args(small), _chunk_args(big)
    assert [(a.shape, str(a.dtype)) for a in jax.tree.leaves(args_s)] == \
           [(a.shape, str(a.dtype)) for a in jax.tree.leaves(args_b)]
    ma_s = small._chunk_fn.lower(*args_s).compile().memory_analysis()
    ma_b = big._chunk_fn.lower(*args_b).compile().memory_analysis()
    if ma_s is not None and ma_b is not None:
        assert ma_s.temp_size_in_bytes == ma_b.temp_size_in_bytes
    rng = np.random.RandomState(0)
    m = big.run_round(rng)
    assert np.isfinite(m["loss"])
    assert big.ledger.rounds == 1
    # bank bytes on device per chunk: K never enters the product
    assert big.host_chunk_device_bytes() == \
        sum(v.nbytes // v.shape[0]
            for v in jax.tree.leaves(big.lbg)) * big._chunk
