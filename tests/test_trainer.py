"""Distributed trainer: both dp modes, LBGM-off equivalence, tau>1 ASG."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import LBGMConfig
from repro.train import trainer as tr


def _cfg(mode="replicated", variant="full", tau=1):
    cfg = get_config("qwen3-1.7b").reduced()
    return dataclasses.replace(
        cfg, dp_mode=mode,
        lbgm=LBGMConfig(variant=variant, delta_threshold=0.2, k_frac=0.1,
                        num_clients=4, local_steps=tau))


def _batch(key, cfg, K, b=2, T=32, tau=1):
    lead = (K, tau, b) if tau > 1 else (K, b)
    toks = jax.random.randint(key, lead + (T,), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}


@pytest.mark.slow
def test_force_full_rounds_matches_no_lbgm(key):
    """delta<0 => every round is a full-gradient round => identical params
    to the LBGM-off baseline (paper takeaway 1 at trainer level)."""
    cfg = _cfg()
    K = 4
    batch = _batch(key, cfg, K)
    s_l, _ = tr.init_train_state(key, cfg, K, use_lbgm=True)
    s_v, _ = tr.init_train_state(key, cfg, K, use_lbgm=False)
    step_l = jax.jit(tr.make_train_step(cfg, K, 0.01, delta=-1.0))
    step_v = jax.jit(tr.make_train_step(cfg, K, 0.01, use_lbgm=False))
    for _ in range(3):
        s_l, m_l = step_l(s_l, batch)
        s_v, m_v = step_v(s_v, batch)
    assert float(m_l["frac_scalar"]) == 0.0
    for k in s_v["params"]:
        np.testing.assert_allclose(np.asarray(s_l["params"][k]),
                                   np.asarray(s_v["params"][k]),
                                   rtol=1e-5, atol=1e-6)


def test_scalar_rounds_kick_in(key):
    cfg = _cfg()
    K = 4
    batch = _batch(key, cfg, K)
    state, _ = tr.init_train_state(key, cfg, K)
    step = jax.jit(tr.make_train_step(cfg, K, 0.005))
    state, m0 = step(state, batch)
    assert float(m0["frac_scalar"]) == 0.0          # LBG init round
    state, m1 = step(state, batch)                  # same batch: tiny sin^2
    assert float(m1["frac_scalar"]) > 0.5
    assert float(m1["uplink_floats"]) < float(m0["uplink_floats"]) / 100


def test_fsdp_scan_clients(key):
    cfg = _cfg(mode="fsdp", variant="topk")
    K = 4
    batch = _batch(key, cfg, K)
    state, _ = tr.init_train_state(key, cfg, K)
    step = jax.jit(tr.make_train_step(cfg, K, 0.01))
    s1, m1 = step(state, batch)
    s2, m2 = step(s1, batch)
    assert float(m2["loss"]) < float(m1["loss"])
    assert np.isfinite(float(m2["mean_sin2"]))


def test_tau_local_steps_asg(key):
    """tau>1 replicated mode runs local SGD and aggregates the ASG."""
    cfg = _cfg(tau=3)
    K = 2
    batch = _batch(key, cfg, K, tau=3)
    state, _ = tr.init_train_state(key, cfg, K)
    step = jax.jit(tr.make_train_step(cfg, K, 0.01))
    s1, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    moved = any(bool(jnp.any(s1["params"][k] != state["params"][k]))
                for k in state["params"])
    assert moved


def test_effective_clients_divisibility():
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(1, 1)
    cfg = _cfg()
    k = tr.effective_clients(cfg, mesh, 256)
    assert 256 % k == 0 and k >= 1
    cfg_f = _cfg(mode="fsdp")
    k2 = tr.effective_clients(cfg_f, mesh, 256)
    assert 256 % k2 == 0 and 1 <= k2 <= cfg_f.lbgm.num_clients
