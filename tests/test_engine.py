"""Oracle-backed tests for the unified federated execution engine.

Three pillars (ISSUE: test archetype):
  (a) LBGM client-step algebra checked against a pure-NumPy float64 oracle
      (no hypothesis dependency — deterministic seeded trials),
  (b) chunked lax.scan scheduler == all-clients vmap scheduler bit-for-bit
      on identical seeds (including non-divisible chunk padding and device
      sampling), plus the O(chunk.M) vs O(K.M) transient-memory model via
      XLA's compiled memory analysis,
  (c) uplink accounting: a scalar (recycle) round uploads exactly 1 float
      per client and total uplink is monotone non-increasing in delta.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import make_uplink_pipeline
from repro.configs import get_config
from repro.core.lbgm import lbgm_client_step, lbgm_stats
from repro.core.tree_math import tree_size
from repro.data.synthetic import mixture_classification
from repro.fed import (DenseLBGStore, FLConfig, FLEngine, NullLBGStore,
                       TopKLBGStore, make_lbg_store, partition_iid,
                       partition_label_skew)
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

# --------------------------------------------------------------- fixtures


@pytest.fixture(scope="module")
def fcn_setup():
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(1500, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=10, noniid=False, **flkw):
    params, x, y, loss_fn = fcn_setup
    parts = (partition_label_skew(y, K, 3, seed=0) if noniid
             else partition_iid(len(y), K, seed=0))
    data = [{"x": x[p], "y": y[p]} for p in parts]
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             **flkw))


# ------------------------------------------------- (a) NumPy oracle tests


def np_lbgm_oracle(g: np.ndarray, l: np.ndarray, delta: float):
    """Float64 reference for Algorithm 1's worker-side decision."""
    EPS = 1e-20
    gl = float(g @ l)
    gg = float(g @ g)
    ll = float(l @ l)
    cos2 = gl * gl / max(gg * ll, EPS)
    sin2 = 1.0 - cos2 if ll > EPS else 1.0
    rho = gl / max(ll, EPS)
    scalar = (sin2 <= delta) and (sin2 < 1.0)
    g_tilde = rho * l if scalar else g
    new_lbg = l if scalar else g
    return sin2, rho, scalar, g_tilde, new_lbg


def _rand_tree(rng, scale=1.0):
    return {"w": jnp.asarray(rng.randn(24).astype(np.float32) * scale),
            "b": jnp.asarray(rng.randn(8).astype(np.float32) * scale)}


def _flat(tree):
    # jax.tree.* canonicalizes dicts to sorted key order; match it so g and
    # lbg flatten with identical leaf order
    return np.concatenate([np.asarray(tree[k], np.float64).ravel()
                           for k in sorted(tree)])


@pytest.mark.parametrize("seed", range(25))
def test_lbgm_stats_match_numpy_oracle(seed):
    rng = np.random.RandomState(seed)
    g, lbg = _rand_tree(rng), _rand_tree(rng, scale=rng.uniform(0.1, 5.0))
    if seed % 5 == 0:        # exercise the near-parallel branch too
        lbg = jax.tree.map(lambda x: 1.5 * x + 1e-3, g)
    if seed % 7 == 0:        # and the degenerate zero-LBG branch
        lbg = jax.tree.map(jnp.zeros_like, g)
    sin2, rho, _ = lbgm_stats(g, lbg)
    ref_sin2, ref_rho, *_ = np_lbgm_oracle(_flat(g), _flat(lbg), 0.5)
    np.testing.assert_allclose(float(sin2), ref_sin2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(rho), ref_rho, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("seed,delta", [(s, d) for s in range(10)
                                        for d in (0.05, 0.5, 0.98)])
def test_lbgm_client_step_matches_numpy_oracle(seed, delta):
    rng = np.random.RandomState(100 + seed)
    g = _rand_tree(rng)
    # mix of near-parallel and generic LBGs so both branches fire
    lbg = (jax.tree.map(lambda x: 0.7 * x, g) if seed % 2
           else _rand_tree(rng))
    noise = _rand_tree(rng, scale=0.05)
    lbg = jax.tree.map(lambda a, n: a + n, lbg, noise)
    gt, new_lbg, stats = lbgm_client_step(g, lbg, delta)
    ref = np_lbgm_oracle(_flat(g), _flat(lbg), delta)
    ref_sin2, ref_rho, ref_scalar, ref_gt, ref_new = ref
    assert bool(stats.sent_scalar) == ref_scalar, (float(stats.sin2), ref)
    np.testing.assert_allclose(_flat(gt), ref_gt, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(_flat(new_lbg), ref_new, rtol=1e-5,
                               atol=1e-6)
    # uplink: scalar round == exactly 1 float, full round == M floats
    m = sum(v.size for v in g.values())
    assert float(stats.uplink_floats) == (1.0 if ref_scalar else float(m))


def test_store_factory_and_null_passthrough():
    cfg_null = FLConfig(use_lbgm=False)
    assert isinstance(make_lbg_store(cfg_null), NullLBGStore)
    assert isinstance(make_lbg_store(FLConfig(lbg_variant="full")),
                      DenseLBGStore)
    assert isinstance(
        make_lbg_store(FLConfig(lbg_variant="topk",
                                lbg_kw={"k_frac": 0.25})), TopKLBGStore)
    with pytest.raises(ValueError):
        make_lbg_store(FLConfig(lbg_variant="bogus"))
    store = NullLBGStore()
    g = {"w": jnp.arange(4.0)}
    gt, lbg, stats = store.client_step(g, store.init(g, 3))
    np.testing.assert_array_equal(np.asarray(gt["w"]), np.asarray(g["w"]))
    assert not bool(stats.sent_scalar)
    assert float(store.full_round_cost(jnp.asarray(7.0), stats)) == 7.0


def test_topk_store_cost_and_state_shapes():
    params = {"w": jnp.zeros((40, 10)), "b": jnp.zeros(16)}
    store = TopKLBGStore(delta_threshold=0.5, k_frac=0.1)
    bank = store.init(params, num_clients=6)
    for leaf in bank.values():
        assert leaf["idx"].shape[0] == 6 and leaf["val"].shape[0] == 6
    total_k = sum(int(v["idx"].size) for v in bank.values()) // 6
    # cost model lives in core/lbgm.py; the store passes it through
    g = {k: jnp.ones(v.shape) for k, v in params.items()}
    lbg_k = jax.tree.map(lambda x: x[0], bank)
    _, _, stats = store.client_step(g, lbg_k)
    assert not bool(stats.sent_scalar)       # zero LBG -> full round
    assert float(store.full_round_cost(jnp.asarray(0.0), stats)) \
        == 1.5 * total_k


def test_seq_weighted_sum_gates_nonfinite_zero_weight_clients():
    """Phantom pad clients may produce NaN gradients from all-zero batches;
    w_k = 0 must keep them out of the aggregate (0 * NaN is NaN)."""
    from repro.fed.engine import _seq_weighted_sum
    gt = {"w": jnp.asarray([[1.0, 2.0], [jnp.nan, jnp.inf]])}
    w = jnp.asarray([0.5, 0.0])
    acc = _seq_weighted_sum({"w": jnp.zeros(2)}, w, gt)
    np.testing.assert_allclose(np.asarray(acc["w"]), [0.5, 1.0])


def test_uplink_pipeline_composition():
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(16)
                          .astype(np.float32))}
    # none: identity, cost = M, residual untouched
    fn, uses_ef = make_uplink_pipeline("none")
    out, res, cost = fn(g, {})
    assert not uses_ef and res == {} and float(cost) == 16.0
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))
    # topk defaults EF on; telescoping invariant holds through the hook
    fn, uses_ef = make_uplink_pipeline("topk", {"k_frac": 0.25})
    assert uses_ef
    residual = {"w": jnp.zeros(16)}
    total_g = np.zeros(16)
    total_c = np.zeros(16)
    rng = np.random.RandomState(3)
    for _ in range(5):
        gt = {"w": jnp.asarray(rng.randn(16).astype(np.float32))}
        c, residual, _ = fn(gt, residual)
        total_g += np.asarray(gt["w"])
        total_c += np.asarray(c["w"])
    np.testing.assert_allclose(total_c + np.asarray(residual["w"]), total_g,
                               rtol=1e-4, atol=1e-5)
    # explicit EF off overrides the topk default
    _, uses_ef = make_uplink_pipeline("topk", {"k_frac": 0.25},
                                      use_error_feedback=False)
    assert not uses_ef


# ----------------------------------------- (b) scheduler equivalence


def _assert_identical_run(fl_a, fl_b, rounds=3):
    ha = fl_a.run(rounds)
    hb = fl_b.run(rounds)
    for k in fl_a.params:
        np.testing.assert_array_equal(np.asarray(fl_a.params[k]),
                                      np.asarray(fl_b.params[k]),
                                      err_msg=k)
    assert ha == hb  # metrics bit-for-bit, every round


@pytest.mark.slow
def test_chunked_equals_vmap_100_clients(fcn_setup):
    """Acceptance: numerically identical params/metrics on a 100-client
    paper_fcn run."""
    kw = dict(use_lbgm=True, delta_threshold=0.2, noniid=True)
    fl_v = make_engine(fcn_setup, K=100, scheduler="vmap", **kw)
    fl_c = make_engine(fcn_setup, K=100, scheduler="chunked", chunk_size=20,
                       **kw)
    _assert_identical_run(fl_v, fl_c, rounds=3)


def test_pick_chunk_prefers_divisors():
    from repro.fed.engine import pick_chunk
    assert pick_chunk(20, 16) == 10     # largest divisor <= 16
    assert pick_chunk(100, 20) == 20    # exact divisor kept
    assert pick_chunk(512, 8) == 8
    assert pick_chunk(6, 100) == 6      # clamps to K
    assert pick_chunk(7, 4) == 4        # prime K: keep size, pad instead
    assert pick_chunk(1, 16) == 1


def test_chunked_equals_vmap_divisor_clamp(fcn_setup):
    """chunk_size not dividing K clamps to a divisor (10 -> blocks of 2),
    no phantom clients."""
    kw = dict(use_lbgm=True, delta_threshold=0.2)
    fl_v = make_engine(fcn_setup, K=10, scheduler="vmap", **kw)
    fl_c = make_engine(fcn_setup, K=10, scheduler="chunked", chunk_size=4,
                       **kw)
    assert fl_c._chunk == 2 and fl_c._pad == 0
    _assert_identical_run(fl_v, fl_c, rounds=3)


def test_chunked_equals_vmap_prime_cohort_padding(fcn_setup):
    """Near-prime K falls back to zero-weight padding of the tail block."""
    kw = dict(use_lbgm=True, delta_threshold=0.2)
    fl_v = make_engine(fcn_setup, K=7, scheduler="vmap", **kw)
    fl_c = make_engine(fcn_setup, K=7, scheduler="chunked", chunk_size=4,
                       **kw)
    assert fl_c._chunk == 4 and fl_c._pad == 1
    _assert_identical_run(fl_v, fl_c, rounds=3)


def test_chunked_equals_vmap_with_pipeline_and_sampling(fcn_setup):
    """Equivalence must survive compressor + EF + Algorithm-3 sampling."""
    kw = dict(use_lbgm=True, delta_threshold=0.3, compressor="topk",
              compressor_kw={"k_frac": 0.1}, error_feedback=True,
              sample_frac=0.6)
    fl_v = make_engine(fcn_setup, K=8, scheduler="vmap", **kw)
    fl_c = make_engine(fcn_setup, K=8, scheduler="chunked", chunk_size=4,
                       **kw)
    _assert_identical_run(fl_v, fl_c, rounds=4)


def test_chunked_padded_tail_with_sampling_and_ef(fcn_setup):
    """Prime K (zero-weight padded tail block) combined with
    Algorithm-3 sampling AND error feedback: the phantom clients' residual
    rows must stay out of every code path."""
    kw = dict(use_lbgm=True, delta_threshold=0.3, compressor="topk",
              compressor_kw={"k_frac": 0.1}, error_feedback=True,
              sample_frac=0.6)
    fl_v = make_engine(fcn_setup, K=7, scheduler="vmap", **kw)
    fl_c = make_engine(fcn_setup, K=7, scheduler="chunked", chunk_size=4,
                       **kw)
    assert fl_c._chunk == 4 and fl_c._pad == 1
    _assert_identical_run(fl_v, fl_c, rounds=4)
    # the phantom pad row of the residual bank never accumulates anything
    for leaf in jax.tree.leaves(fl_c.residual):
        np.testing.assert_array_equal(np.asarray(leaf[-1]),
                                      np.zeros_like(leaf[-1]))


@pytest.mark.slow
def test_chunked_equals_vmap_topk_store(fcn_setup):
    """Equivalence with the sparse LBG bank."""
    kw = dict(use_lbgm=True, delta_threshold=0.5, lbg_variant="topk",
              lbg_kw={"k_frac": 0.25})
    fl_v = make_engine(fcn_setup, K=6, scheduler="vmap", **kw)
    fl_c = make_engine(fcn_setup, K=6, scheduler="chunked", chunk_size=3,
                       **kw)
    _assert_identical_run(fl_v, fl_c, rounds=3)


def _round_memory(fl):
    """(temp, total) bytes of the compiled round program: temp is XLA's
    transient working set; total is the whole peak footprint
    (args + outputs + temps, minus donated-alias double counting)."""
    sds = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    batch = fl._sample_batches(np.random.RandomState(0))
    mask = jnp.ones(fl.cfg.num_clients, jnp.float32)
    lowered = fl._round.lower(sds(fl.params), sds(fl.lbg),
                              sds(fl.residual), sds(batch), sds(mask))
    stats = lowered.compile().memory_analysis()
    if stats is None or not hasattr(stats, "temp_size_in_bytes"):
        pytest.skip("backend does not expose compiled memory stats")
    temp = int(stats.temp_size_in_bytes)
    total = (temp + int(stats.argument_size_in_bytes)
             + int(stats.output_size_in_bytes)
             - int(stats.alias_size_in_bytes))
    return temp, total


@pytest.mark.slow
def test_512_clients_chunked_within_100_client_vmap_envelope(fcn_setup):
    """Acceptance: a 512-client chunked round (sparse LBG bank, blocks of
    8) fits the memory envelope of the 100-client vmap round — transient
    working set AND total peak footprint — and the cohort actually
    trains. This is the O(chunk·M) vs O(K·M) claim end-to-end: the dense
    bank is the one O(K·M) term left, so the large cohort pairs the
    chunked scheduler with the TopK store."""
    fl_vmap100 = make_engine(fcn_setup, K=100, use_lbgm=True,
                             delta_threshold=0.2, scheduler="vmap")
    fl_chunk512 = make_engine(fcn_setup, K=512, use_lbgm=True,
                              delta_threshold=0.2, scheduler="chunked",
                              chunk_size=8, lbg_variant="topk",
                              lbg_kw={"k_frac": 0.1})
    t100, tot100 = _round_memory(fl_vmap100)
    t512, tot512 = _round_memory(fl_chunk512)
    assert t512 <= t100, (t512, t100)
    assert tot512 <= tot100, (tot512, tot100)
    hist = fl_chunk512.run(3)
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["loss"] <= hist[0]["loss"] * 1.05


def test_chunked_temp_memory_below_vmap(fcn_setup):
    """Same-cohort version of the envelope claim, cheap enough for tier 1:
    chunking K=32 into blocks of 4 must shrink the round's XLA temp
    allocation."""
    kw = dict(use_lbgm=True, delta_threshold=0.2)
    t_vmap, _ = _round_memory(
        make_engine(fcn_setup, K=32, scheduler="vmap", **kw))
    t_chunk, _ = _round_memory(
        make_engine(fcn_setup, K=32, scheduler="chunked", chunk_size=4,
                    **kw))
    assert t_chunk < t_vmap, (t_chunk, t_vmap)


def test_unknown_scheduler_rejected(fcn_setup):
    with pytest.raises(ValueError):
        make_engine(fcn_setup, K=4, scheduler="warp")


def test_empty_client_rejected_with_actionable_error(fcn_setup):
    """A starved partition (possible when label-skew demand > supply) must
    fail at engine construction with the offending clients named, not deep
    inside batch sampling as rng.randint(0, 0)."""
    params, x, y, loss_fn = fcn_setup
    data = [{"x": x[:5], "y": y[:5]},
            {"x": x[:0], "y": y[:0]},
            {"x": x[5:9], "y": y[5:9]}]
    with pytest.raises(ValueError, match=r"clients \[1\] have no training"):
        FLEngine(loss_fn, params, data, FLConfig(num_clients=3))


# ----------------------------------------- (c) uplink accounting


def test_scalar_rounds_cost_exactly_one_float(fcn_setup):
    """delta=1.0 => every post-refresh round recycles: K floats/round."""
    K = 6
    fl = make_engine(fcn_setup, K=K, use_lbgm=True, delta_threshold=1.0)
    hist = fl.run(4)
    M = tree_size(fl.params)
    assert hist[0]["uplink_floats"] == pytest.approx(K * M)   # refresh
    for h in hist[1:]:
        assert h["uplink_floats"] == K * 1.0                  # 1 float each
        assert h["frac_scalar"] == 1.0
    assert fl.vanilla_uplink == pytest.approx(4 * K * M)
    assert hist[-1]["savings"] == pytest.approx(
        1.0 - (K * M + 3 * K) / (4 * K * M))


def test_savings_monotone_in_delta(fcn_setup):
    """Larger delta => recycle at least as often => total uplink does not
    grow (paper Fig. 6 trend)."""
    totals = []
    for delta in (-1.0, 0.3, 0.95):
        fl = make_engine(fcn_setup, K=8, use_lbgm=True,
                         delta_threshold=delta, noniid=True)
        fl.run(6)
        totals.append(fl.total_uplink)
    assert totals[0] >= totals[1] >= totals[2]
    # delta=-1 never recycles: exact vanilla cost
    assert totals[0] == pytest.approx(6 * 8 * tree_size(fl.params))


def test_metrics_keys_and_history_accumulation(fcn_setup):
    fl = make_engine(fcn_setup, K=4, use_lbgm=True, delta_threshold=0.2)
    m = fl.run_round(np.random.RandomState(0))
    for k in ("loss", "uplink_floats", "frac_scalar", "total_uplink",
              "vanilla_uplink", "savings"):
        assert k in m
    assert fl.history[-1] is m
    assert m["total_uplink"] == pytest.approx(m["uplink_floats"])


def test_engine_accounting_unified_on_comm_ledger(fcn_setup):
    """The engine's uplink accounting is the CommLedger — one source of
    truth, no hand-rolled duplicate counters (ISSUE 3 accounting drift)."""
    from repro.comm.accounting import CommLedger
    fl = make_engine(fcn_setup, K=4, use_lbgm=True, delta_threshold=0.2)
    assert isinstance(fl.ledger, CommLedger)
    rng = np.random.RandomState(0)
    for _ in range(3):
        m = fl.run_round(rng)
    assert fl.ledger.rounds == 3 and len(fl.ledger.per_round) == 3
    # history fields ARE ledger fields
    assert m["total_uplink"] == fl.ledger.uplink_floats
    assert m["vanilla_uplink"] == fl.ledger.vanilla_floats
    assert m["savings"] == fl.ledger.savings
    # engine-level views derive from the ledger
    assert fl.total_uplink == fl.ledger.uplink_floats
    assert fl.vanilla_uplink == fl.ledger.vanilla_floats
    assert m["uplink_floats"] == pytest.approx(
        fl.ledger.per_round[-1]["uplink"])
    # pre-run: the ledger's 0/0 guard reports zero savings (the old
    # hand-rolled max(vanilla, 1.0) guard disagreed with it)
    assert CommLedger().savings == 0.0


# ----------------------------------------- (d) round RNG stream hygiene


def test_empty_cohort_fallback_preserves_rng_stream(fcn_setup):
    """The empty-mask fallback must not consume extra RNG state: a config
    that hits one unlucky round would otherwise diverge from its sibling
    on every later round's batch/mask stream (ISSUE 3 RNG perturbation)."""
    # sample_frac so small every draw comes up empty -> fallback each round
    fl = make_engine(fcn_setup, K=5, use_lbgm=True, sample_frac=1e-12)
    rng = np.random.RandomState(7)
    ref = np.random.RandomState(7)
    u = ref.rand(5)
    mask = fl._sample_mask(rng)
    # fallback picked exactly one client: the one closest to its threshold
    assert mask.sum() == 1.0 and mask[int(np.argmin(u))] == 1.0
    # ...and consumed exactly num_clients uniforms: streams stay in lockstep
    np.testing.assert_array_equal(rng.rand(8), ref.rand(8))
    # sample_frac == 1 consumes nothing
    fl_full = make_engine(fcn_setup, K=5, use_lbgm=True)
    rng2 = np.random.RandomState(7)
    assert fl_full._sample_mask(rng2).sum() == 5.0
    np.testing.assert_array_equal(rng2.rand(3),
                                  np.random.RandomState(7).rand(3))


def test_sampled_and_unsampled_runs_share_batch_stream(fcn_setup):
    """Two engines differing only in whether round 1 hit the empty-cohort
    fallback draw identical batches for round 2 (stream invariance
    end-to-end, not just in _sample_mask)."""
    fl_a = make_engine(fcn_setup, K=5, use_lbgm=True, sample_frac=1e-12)
    fl_b = make_engine(fcn_setup, K=5, use_lbgm=True, sample_frac=0.99)
    rng_a, rng_b = np.random.RandomState(3), np.random.RandomState(3)
    fl_a.run_round(rng_a)   # fallback path
    fl_b.run_round(rng_b)   # normal path
    ba = fl_a._sample_batches(rng_a)
    bb = fl_b._sample_batches(rng_b)
    for k in ba:
        np.testing.assert_array_equal(np.asarray(ba[k]), np.asarray(bb[k]))
