"""End-to-end behaviour tests: the whole system wired together, plus a
subprocess mini dry-run on a real multi-device (host-platform) mesh."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    """The public training driver: loss decreases and LBGM saves uplink."""
    from repro.launch.train import main
    hist = main(["--arch", "qwen3-1.7b", "--reduced", "--steps", "30",
                 "--seq", "64", "--batch", "4", "--clients", "4",
                 "--lr", "0.01", "--delta", "0.6", "--pool", "1",
                 "--out", str(tmp_path), "--log-every", "1000"])
    assert hist[-1]["loss"] < hist[0]["loss"]
    scalar_rounds = sum(h.get("frac_scalar", 0) > 0 for h in hist)
    assert scalar_rounds > 0          # gradient recycling actually happened
    assert os.path.exists(os.path.join(tmp_path, "final.npz"))


def test_serve_driver_end_to_end():
    from repro.launch.serve import main
    gen = main(["--arch", "rwkv6-3b", "--reduced", "--batch", "2",
                "--prompt-len", "4", "--gen", "3"])
    assert gen.shape == (2, 3)


@pytest.mark.slow
def test_mini_dryrun_on_multi_device_mesh(tmp_path):
    """lower+compile a reduced arch on a real 2x4 host-device mesh in a
    subprocess (so the 8-device override never leaks into this process)."""
    script = r"""
import os
import json
import dataclasses
# importing dryrun sets XLA_FLAGS=...512 (its required first lines);
# override to 8 afterwards, BEFORE jax initializes devices
import repro.launch.dryrun as dr
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
import repro.configs.base as base

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = get_config("qwen3-1.7b").reduced()
orig = dr.get_config
dr.get_config = lambda name: cfg if name == "mini" else orig(name)
dr.INPUT_SHAPES["mini_train"] = base.ShapeConfig("mini_train", 64, 8, "train")
dr.INPUT_SHAPES["mini_decode"] = base.ShapeConfig("mini_decode", 64, 8,
                                                  "decode")
row = dr.lower_pair("mini", "mini_train", mesh, "mesh2x4")
assert row["status"] == "ok", row
row2 = dr.lower_pair("mini", "mini_decode", mesh, "mesh2x4")
assert row2["status"] == "ok", row2
print(json.dumps({"collective_count": row["collectives"]["count"],
                  "coll_bytes": row["coll_bytes_per_dev"]}))
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    # data-parallel LBGM aggregation must produce real collectives
    assert payload["collective_count"] > 0
    assert payload["coll_bytes"] > 0


def test_fl_plus_pca_pipeline(key):
    """Track the gradient space of an FL run and confirm (H1): N99 well
    below the number of rounds."""
    from repro.analysis.pca import GradientSpaceTracker
    from repro.configs import get_config
    from repro.data.synthetic import mixture_classification
    from repro.fed import FLConfig, FLEngine, partition_iid
    from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

    cfg = get_config("paper-fcn")
    params, _ = init_fcn(key, cfg)
    x, y = mixture_classification(800, 10, seed=3)
    parts = partition_iid(len(y), 8, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    fl = FLEngine(loss_fn, params, data,
                  FLConfig(num_clients=8, tau=2, lr=0.05, batch_size=16))
    tracker = GradientSpaceTracker()
    rng = np.random.RandomState(0)
    prev = jax.tree.map(lambda a: np.asarray(a, np.float64), fl.params)
    for r in range(20):
        fl.run_round(rng)
        cur = jax.tree.map(lambda a: np.asarray(a, np.float64), fl.params)
        tracker.add(jax.tree.map(lambda a, b: a - b, prev, cur))
        prev = cur
    s = tracker.summary()
    assert s["n99_final"] < 20          # (H1): far fewer PGDs than rounds
    assert s["n95_final"] <= s["n99_final"]
