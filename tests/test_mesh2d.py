"""2-D (clients x model) mesh execution (ISSUE 5 tentpole).

Acceptance:
  * ``FLConfig.mesh`` generalizes to a JSON-able spec — ``None`` / int
    (the pre-2-D spelling, still valid) / ``[clients, model]`` — and
    round-trips losslessly;
  * a ``(1, 1)`` mesh reproduces the chunked scheduler bit-for-bit and an
    int spec ``n`` is bit-identical to ``[n, 1]``;
  * on a real multi-device 2-D mesh (forced host devices, subprocess) the
    round history matches chunked within fp32 tolerance with IDENTICAL
    uplink accounting, the sparse bank physically shards along BOTH axes,
    and per-device bank bytes scale as O(K·k_frac·M / (c·m));
  * ``RoundPrefetcher`` x "sharded" interplay: a mid-run host-prep
    exception propagates to the caller, and the prefetch path is
    rng-stream invariant under the 2-D mesh.

ISSUE-8 additions (``FLConfig.model_sharding``):
  * the knob validates and JSON round-trips; ``"auto"`` requires the
    sharded scheduler and a metadata-carrying model component;
  * on 8 forced host devices (subprocess), ``"auto"`` on the ``"lm"``
    component matches ``"replicate"`` within fp32 tolerance with
    IDENTICAL uplink accounting, params physically shard 1/m per model
    rank, and the whole-round per-device memory envelope shrinks;
  * a ``[1, 1]`` mesh under the default ``"replicate"`` still reproduces
    the pre-PR golden history float-exact even with 8 devices visible.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_iid
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def fcn_setup():
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(900, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=10, **flkw):
    params, x, y, loss_fn = fcn_setup
    parts = partition_iid(len(y), K, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             **flkw))


def _assert_identical_run(fl_a, fl_b, rounds=3):
    ha = fl_a.run(rounds)
    hb = fl_b.run(rounds)
    for k in fl_a.params:
        np.testing.assert_array_equal(np.asarray(fl_a.params[k]),
                                      np.asarray(fl_b.params[k]), err_msg=k)
    assert ha == hb


# ------------------------------------------------------- mesh spec knob


def test_mesh_spec_validation():
    # int spelling unchanged (and still rejected when invalid)
    assert FLConfig(scheduler="sharded", mesh=1).mesh == 1
    with pytest.raises(ValueError, match="mesh"):
        FLConfig(mesh=0)
    # 2-D spelling: [clients, model], both >= 1, exactly two entries
    assert FLConfig(scheduler="sharded", mesh=[2, 2]).mesh == [2, 2]
    for bad in ([0, 2], [2, 0], [2], [2, 2, 2], [2.0, 2], True, [True, 2],
                "2x2"):
        with pytest.raises(ValueError, match="mesh"):
            FLConfig(scheduler="sharded", mesh=bad)
    # tuples normalize to lists so a JSON round-trip compares equal
    cfg = FLConfig(scheduler="sharded", mesh=(2, 2))
    assert cfg.mesh == [2, 2]
    assert FLConfig.from_dict(json.loads(json.dumps(cfg.to_dict()))) == cfg
    # model-axis sharding needs the mesh-aware scheduler
    with pytest.raises(ValueError, match="sharded"):
        FLConfig(scheduler="chunked", mesh=[1, 2])
    assert FLConfig(scheduler="chunked").mesh is None  # int-free default ok


def test_mesh_shape_views():
    assert FLConfig().mesh_shape is None
    assert FLConfig().mesh_model_dim == 1
    assert FLConfig(scheduler="sharded", mesh=3).mesh_shape == (3, 1)
    assert FLConfig(scheduler="sharded", mesh=[2, 4]).mesh_shape == (2, 4)
    assert FLConfig(scheduler="sharded", mesh=[2, 4]).mesh_model_dim == 4


def test_make_fl_mesh_shapes_and_errors():
    from repro.launch.mesh import make_fl_mesh
    n = len(jax.devices())
    mesh = make_fl_mesh(None)
    assert mesh.axis_names == ("clients", "model")
    assert dict(mesh.shape) == {"clients": n, "model": 1}
    mesh = make_fl_mesh(1)
    assert dict(mesh.shape) == {"clients": 1, "model": 1}
    mesh = make_fl_mesh([1, 1], client_axis="c", model_axis="m")
    assert mesh.axis_names == ("c", "m")
    with pytest.raises(RuntimeError, match="device"):
        make_fl_mesh([n + 1, 1])
    with pytest.raises(RuntimeError, match="device"):
        make_fl_mesh([1, n + 1])
    with pytest.raises(ValueError, match="axis"):
        make_fl_mesh([0, 1])


def test_bank_model_partition_rule():
    from repro.core.lbgm_sharded import (bank_model_partition,
                                         model_shard_rows)
    # nb rounds to 16 for multi-block leaves -> power-of-two meshes divide
    assert model_shard_rows(16, 4) == 4
    assert model_shard_rows(16, 1) == 0      # n_model=1: nothing to shard
    assert model_shard_rows(1, 4) == 0       # single-block leaf: replicated
    assert model_shard_rows(16, 3) == 0      # non-divisible: replicated
    params = {"big": jnp.zeros((700, 128)), "small": jnp.zeros(64)}
    part = bank_model_partition(params, 0.1, 4)
    assert part == {"big": True, "small": False}


def test_spec_with_2d_mesh_roundtrips(tmp_path):
    from repro.fed import ExperimentSpec
    spec = ExperimentSpec.from_dict({
        "name": "mesh2d",
        "fl": {"num_clients": 8, "scheduler": "sharded", "chunk_size": 4,
               "mesh": [2, 4], "lbg_variant": "topk-sharded"},
    })
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec and again.fl.mesh == [2, 4]
    assert json.loads(spec.to_json())["fl"]["mesh"] == [2, 4]
    path = tmp_path / "spec.json"
    spec.save(str(path))
    assert ExperimentSpec.load(str(path)) == spec


# ------------------------------------------------- model_sharding knob


def test_model_sharding_knob_validation():
    assert FLConfig().model_sharding == "replicate"
    cfg = FLConfig(scheduler="sharded", mesh=[1, 1],
                   model_sharding="auto")
    assert cfg.model_sharding == "auto"
    # JSON round-trip (from_dict rejects unknown keys, so the knob being
    # round-trippable proves it is a first-class serialized field)
    assert FLConfig.from_dict(json.loads(json.dumps(cfg.to_dict()))) == cfg
    with pytest.raises(ValueError, match="model_sharding"):
        FLConfig(model_sharding="tp")
    # tensor-parallel client compute only exists on the sharded scheduler
    with pytest.raises(ValueError, match="sharded"):
        FLConfig(scheduler="chunked", model_sharding="auto")


def test_model_sharding_auto_needs_axes_metadata(fcn_setup):
    """The FCN component carries no axes tree: engine construction must
    fail actionably, not at trace time."""
    with pytest.raises(ValueError, match="sharding metadata"):
        make_engine(fcn_setup, K=6, scheduler="sharded", mesh=[1, 1],
                    chunk_size=3, use_lbgm=True, delta_threshold=0.2,
                    lbg_variant="topk-sharded", lbg_kw={"k_frac": 0.25},
                    model_sharding="auto")


def test_model_sharding_auto_rejects_compressor(fcn_setup):
    """auto + a compressor pipeline is refused (its top-k would hit
    model-sharded gradients in GSPMD auto-land); axes are checked first,
    so hand a fake tree to reach the compressor check."""
    params, x, y, loss_fn = fcn_setup
    parts = partition_iid(len(y), 6, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    axes = {k: ("hidden",) * v.ndim for k, v in params.items()}
    with pytest.raises(ValueError, match="compressor"):
        FLEngine(loss_fn, params, data,
                 FLConfig(num_clients=6, tau=2, lr=0.05, batch_size=16,
                          scheduler="sharded", mesh=[1, 1], chunk_size=3,
                          use_lbgm=True, delta_threshold=0.2,
                          lbg_variant="topk-sharded",
                          lbg_kw={"k_frac": 0.25}, compressor="topk",
                          compressor_kw={"k_frac": 0.1},
                          model_sharding="auto"),
                 model_axes=axes)


# ----------------------------------------- (1,1) / int-vs-list equivalence


def test_1x1_mesh_equals_chunked_bitforbit(fcn_setup):
    """Acceptance: mesh=[1,1] reproduces the chunked scheduler exactly."""
    kw = dict(use_lbgm=True, delta_threshold=0.5, chunk_size=3,
              lbg_variant="topk", lbg_kw={"k_frac": 0.25})
    fl_c = make_engine(fcn_setup, K=6, scheduler="chunked", **kw)
    kw["lbg_variant"] = "topk-sharded"
    fl_s = make_engine(fcn_setup, K=6, scheduler="sharded", mesh=[1, 1],
                       **kw)
    assert (fl_s.sched.n_client_dev, fl_s.sched.n_model) == (1, 1)
    _assert_identical_run(fl_c, fl_s, rounds=3)


def test_int_mesh_equals_2d_mesh_bitforbit(fcn_setup):
    """Compatibility rule: mesh=n is exactly mesh=[n, 1]."""
    kw = dict(use_lbgm=True, delta_threshold=0.2, chunk_size=5,
              scheduler="sharded", lbg_variant="topk-sharded",
              lbg_kw={"k_frac": 0.25})
    fl_int = make_engine(fcn_setup, K=10, mesh=1, **kw)
    fl_2d = make_engine(fcn_setup, K=10, mesh=[1, 1], **kw)
    _assert_identical_run(fl_int, fl_2d, rounds=3)


def test_mesh_topk_step_n_model_1_is_local_step():
    """make_mesh_topk_step(n_model=1) must BE the device-local step (the
    bit-for-bit (n, 1) contract rides on sharing that code path)."""
    from repro.core.lbgm_sharded import make_mesh_topk_step
    step = make_mesh_topk_step(0.5, 0.25, n_model=1, sparse_out=True)
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(40, 8).astype(np.float32))}
    from repro.core.lbgm import init_topk_lbg, lbgm_topk_client_step
    lbg = init_topk_lbg(g, 0.25)
    (send, gscale), new_lbg, stats = step(g, lbg)
    (send_r, gscale_r), new_r, stats_r = lbgm_topk_client_step(
        g, lbg, 0.5, 0.25, sparse_out=True)
    for a, b in zip(jax.tree.leaves((send, gscale, new_lbg, tuple(stats))),
                    jax.tree.leaves((send_r, gscale_r, new_r,
                                     tuple(stats_r)))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # n_model > 1 refuses the dense-scatter contract with the fix named
    with pytest.raises(ValueError, match="sparse_out"):
        make_mesh_topk_step(0.5, 0.25, n_model=2, sparse_out=False)


# ------------------------------- RoundPrefetcher x sharded interplay


def test_prefetch_exception_propagates_midrun_sharded(fcn_setup):
    """A host-prep failure on the prefetch thread must surface as the
    documented RuntimeError at the next round, not hang or vanish."""
    fl = make_engine(fcn_setup, K=6, scheduler="sharded", mesh=[1, 1],
                     chunk_size=3, use_lbgm=True, delta_threshold=0.2)
    calls = {"n": 0}
    orig = fl._sample_batches

    def failing(rng):
        calls["n"] += 1
        if calls["n"] >= 3:
            raise RuntimeError("host prep exploded")
        return orig(rng)

    fl._sample_batches = failing
    src = fl.prefetcher(np.random.RandomState(1), depth=1)
    try:
        fl.run_round(src)  # rounds staged before the failure still run
        with pytest.raises(RuntimeError, match="prefetch thread failed"):
            for _ in range(4):
                fl.run_round(src)
        # the cause chain carries the real error
        with pytest.raises(RuntimeError) as ei:
            src.next()
        assert "host prep exploded" in str(ei.value.__cause__)
    finally:
        src.close()


@pytest.mark.slow
def test_prefetch_rng_stream_invariant_under_2d_mesh(fcn_setup):
    """Prefetched and synchronous runs draw the same stream — history and
    params bit-identical — under the 2-D sharded scheduler."""
    kw = dict(scheduler="sharded", mesh=[1, 1], chunk_size=3,
              use_lbgm=True, delta_threshold=0.3, sample_frac=0.7,
              lbg_variant="topk-sharded", lbg_kw={"k_frac": 0.25})
    fl_pre = make_engine(fcn_setup, K=6, **kw)
    fl_sync = make_engine(fcn_setup, K=6, **kw)
    h_pre = fl_pre.run(4, prefetch=True)
    h_sync = fl_sync.run(4, prefetch=False)
    assert h_pre == h_sync
    for k in fl_pre.params:
        np.testing.assert_array_equal(np.asarray(fl_pre.params[k]),
                                      np.asarray(fl_sync.params[k]))


# --------------------------------------------- multi-device 2-D (forced)

MULTI_DEV_2D_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import json
import jax
import numpy as np
from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_iid
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

assert len(jax.devices()) == 8
# widen the FCN so fc1/w spans >1 block (nb -> 16): the model axis has
# real rows to shard
cfg = dataclasses.replace(get_config("paper-fcn"), d_model=512)
params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
x, y = mixture_classification(600, 10, seed=0)
loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
parts = partition_iid(len(y), 8, seed=0)
data = [{"x": x[p], "y": y[p]} for p in parts]

def eng(**kw):
    base = dict(num_clients=8, tau=2, lr=0.05, batch_size=16,
                use_lbgm=True, delta_threshold=0.6, chunk_size=4,
                sample_frac=0.8, lbg_kw={"k_frac": 0.25})
    base.update(kw)
    return FLEngine(loss_fn, params, data, FLConfig(**base))

fc = eng(scheduler="chunked", lbg_variant="topk")
f81 = eng(scheduler="sharded", mesh=[8, 1], lbg_variant="topk-sharded")
f24 = eng(scheduler="sharded", mesh=[2, 4], lbg_variant="topk-sharded")

# --- bank placement: both axes, exactly where the issue says
ms = f24.sched._msharded
assert ms["fc1/w"] is True and ms["fc1/b"] is False, ms
specs = {k: str(l["idx"].sharding.spec) for k, l in f24.lbg.items()}
assert specs["fc1/w"] == "PartitionSpec(None, 'clients', 'model')", specs
assert specs["fc1/b"] == "PartitionSpec(None, 'clients')", specs

# --- per-device bank bytes scale as O(K·k_frac·M / (c·m)) for the
# model-shardable leaf: each of the 8 devices holds exactly 1/(2*4) of
# the global bank rows
g = f24.lbg["fc1/w"]["val"]
n_chunks, chunk, nb, kb = g.shape
local = g.addressable_shards[0].data.shape
assert local == (n_chunks, chunk // 2, nb // 4, kb), (g.shape, local)
assert g.size // 8 == int(np.prod(local)), (g.size, local)
# ...and the (8, 1) client-only mesh holds 1/8 along clients alone
g81 = f81.lbg["fc1/w"]["val"]
local81 = g81.addressable_shards[0].data.shape
assert local81 == (g81.shape[0], g81.shape[1] // 8) + g81.shape[2:]

# --- equivalence: chunked vs (8,1) vs (2,4)
hc = fc.run(3)
h81 = f81.run(3)
h24 = f24.run(3)
# round 1 enters with bit-identical params => uplink accounting is EXACT,
# and the global block layout is mesh-shape independent, so every mesh
# shape reports the same round-1 full-round cost
assert hc[0]["uplink_floats"] == h81[0]["uplink_floats"] \
    == h24[0]["uplink_floats"], (hc[0], h81[0], h24[0])
assert hc[0]["frac_scalar"] == h81[0]["frac_scalar"] \
    == h24[0]["frac_scalar"]
M = sum(int(v.size) for v in params.values())
flip = 1.5 * 0.25 * M  # one client's full-round topk cost
for a, b in zip(hc, h24):
    np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5, atol=1e-7)
    assert abs(a["uplink_floats"] - b["uplink_floats"]) <= 2 * flip, (a, b)
for a, b in zip(hc, h81):
    np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5, atol=1e-7)
for k in fc.params:
    np.testing.assert_allclose(np.asarray(fc.params[k]),
                               np.asarray(f24.params[k]),
                               rtol=1e-5, atol=1e-6, err_msg=k)
    np.testing.assert_allclose(np.asarray(fc.params[k]),
                               np.asarray(f81.params[k]),
                               rtol=1e-5, atol=1e-6, err_msg=k)

# --- XLA memory model (as in test_engine.py): at a FIXED client-axis
# width, growing the model axis must not grow the per-device transient
# set — training stays O(chunk·M / c) per device while the decision +
# aggregation rows it used to hold whole now shard m ways. (The compiled
# stats are whole-program across all mesh devices; divide by the device
# count for the per-device view.)
def round_memory(fl):
    import jax.numpy as jnp
    batch = fl._sample_batches(np.random.RandomState(0))
    mask = jnp.ones(fl.cfg.num_clients, jnp.float32)
    # lower on the live arrays (banks keep their mesh shardings; the
    # uncommitted host args place exactly as in run_round)
    lowered = fl._round.lower(fl.params, fl.lbg, fl.residual, batch, mask)
    stats = lowered.compile().memory_analysis()
    if stats is None or not hasattr(stats, "temp_size_in_bytes"):
        return None
    return int(stats.temp_size_in_bytes)

f21 = eng(scheduler="sharded", mesh=[2, 1], lbg_variant="topk-sharded")
assert f21.sched.chunk == f24.sched.chunk  # same client width per device
t21, t24 = round_memory(f21), round_memory(f24)
mem = {"t21_per_dev": t21 and t21 // 2, "t24_per_dev": t24 and t24 // 8}
if t21 is not None and t24 is not None and t21 > 0:
    assert t24 / 8 <= 1.05 * (t21 / 2), mem
print(json.dumps({"ok": True, "mem": mem}))
"""


@pytest.mark.slow
def test_2d_mesh_multi_device_matches_chunked():
    """Acceptance: 2x4 and 8x1 meshes match chunked within fp32 tolerance
    with identical uplink accounting; the bank shards along both axes with
    per-device bytes divided by c*m (subprocess: forced host devices)."""
    _run_forced_8dev(MULTI_DEV_2D_SCRIPT)


def _run_forced_8dev(script):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", script],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]


# --------------------------------- model_sharding="auto" (forced 8-dev)

MODEL_SHARDING_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro.fed import ExperimentSpec, run_experiment
from repro.fed.experiment import build_experiment

assert len(jax.devices()) == 8
C, M = 2, 4
base = {
    "name": "lm-model-sharding",
    "model": {"name": "lm",
              "kw": {"arch": "yi-34b", "reduced": True,
                     "vocab_size": 1024}},
    "data": {"name": "markov", "kw": {"n": 256, "n_eval": 0,
                                      "seq_len": 32, "vocab": 1024}},
    "partition": {"name": "iid", "kw": {}},
    "fl": {"num_clients": 8, "tau": 2, "lr": 0.02, "batch_size": 4,
           "use_lbgm": True, "delta_threshold": 0.5, "seed": 0,
           "scheduler": "sharded", "chunk_size": 4, "mesh": [C, M],
           "lbg_variant": "topk-sharded", "lbg_kw": {"k_frac": 0.01}},
    "rounds": 3,
    "eval": {"every": 0, "final": False, "verbose": False},
}
spec_r = ExperimentSpec.from_dict(base)
spec_a = dataclasses.replace(
    spec_r, fl=dataclasses.replace(spec_r.fl, model_sharding="auto"))

# --- (b) physical placement under auto: every leaf's addressable shard
# is exactly its resolved PartitionSpec's slice — model-parallel leaves
# hold 1/M of their rows, vocab-axis leaves shard along d_model (their
# gathers must stay device-local), norms replicate
eng_a, _ = build_experiment(spec_a)
specs = eng_a.sched._auto_specs
tot = loc = 0
sharded_leaves = 0
for k, v in eng_a.params.items():
    spec = tuple(specs[k]) + (None,) * (v.ndim - len(tuple(specs[k])))
    exp = tuple(d // (M if s == "model" else 1)
                for d, s in zip(v.shape, spec))
    got = v.addressable_shards[0].data.shape
    assert got == exp, (k, spec, v.shape, got, exp)
    tot += v.size
    loc += int(np.prod(got))
    sharded_leaves += "model" in spec
assert sharded_leaves >= 8, specs    # attn QKV/O, MLP, embed, lm_head
assert tuple(specs["embed"]) == (None, "model"), specs["embed"]
assert tuple(specs["lm_head"]) == ("model", None), specs["lm_head"]
# replicated leaves are only the tiny norms: per-device param bytes stay
# within a hair of the 1/M floor
assert loc / tot <= 1 / M + 0.02, (loc, tot)

# --- (a) histories: fp32-tolerance losses, EXACT uplink accounting (the
# global block layout is mesh- and sharding-mode-independent)
res_r = run_experiment(spec_r)
res_a = run_experiment(spec_a)
for a, b in zip(res_r.records, res_a.records):
    np.testing.assert_allclose(a.loss, b.loss, rtol=1e-5, atol=1e-7)
    assert a.uplink_floats == b.uplink_floats, (a, b)
    assert a.frac_scalar == b.frac_scalar, (a, b)

# --- (b2) whole-round memory envelope (XLA memory_analysis; params
# exact from the shards above). The 1/M scaling lands on the
# param-shaped buffers: per-device param bytes hit the 1/M floor in (b),
# and here auto's per-device footprint must fit inside replicate's
# transient pool plus a 1/M share of the param bytes. The transient pool
# itself is NOT asserted to shrink by 1/M at this toy width — it is
# dominated by state that is model-sharded identically in BOTH modes
# (the look-back banks / sparse-aggregation carry) plus mesh-invariant
# batch buffers, so auto only has to not regress it.
def round_memory(fl):
    batch = fl._sample_batches(np.random.RandomState(0))
    mask = jnp.ones(fl.cfg.num_clients, jnp.float32)
    lowered = fl._round.lower(fl.params, fl.lbg, fl.residual, batch, mask)
    stats = lowered.compile().memory_analysis()
    if stats is None or not hasattr(stats, "temp_size_in_bytes"):
        return None
    return int(stats.temp_size_in_bytes)

eng_r, _ = build_experiment(spec_r)
t_r, t_a = round_memory(eng_r), round_memory(eng_a)
mem = {"t_r_per_dev": t_r and t_r // 8, "t_a_per_dev": t_a and t_a // 8,
       "p_r_per_dev": 4 * tot, "p_a_per_dev": 4 * loc}
if t_r is not None and t_a is not None and t_r > 0:
    assert t_a <= 1.05 * t_r, mem                      # transients: no regression
    comb_a = 4 * loc + t_a / 8
    bound = (1 / M + 0.02) * (4 * tot) + t_r / 8       # 1/M param share
    assert comb_a <= bound, mem
print(json.dumps({"ok": True, "mem": mem}))
"""


GOLDEN_11_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np
from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_label_skew
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

assert len(jax.devices()) == 8
# exactly the test_wire.py golden fixture config, pinned to mesh=[1, 1]
# and the default model_sharding="replicate": 8 visible devices and the
# new auto machinery must leave this path bit-for-bit untouched
cfg = get_config("paper-fcn")
params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
x, y = mixture_classification(1200, 10, seed=0)
loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
parts = partition_label_skew(y, 6, 3, seed=0)
data = [{"x": x[p], "y": y[p]} for p in parts]
fl = FLEngine(loss_fn, params, data,
              FLConfig(num_clients=6, tau=2, lr=0.05, batch_size=16,
                       use_lbgm=True, delta_threshold=0.2,
                       sample_frac=0.7, scheduler="sharded", chunk_size=4,
                       mesh=[1, 1], model_sharding="replicate",
                       lbg_variant="topk-sharded",
                       lbg_kw={"k_frac": 0.25}))
with open(@GOLDEN@) as f:
    golden = json.load(f)["sharded"]
rng = np.random.RandomState(0)
for r, gh in enumerate(golden):
    h = fl.run_round(rng)
    for k, v in gh.items():
        assert float.fromhex(v) == h[k], (r, k, v, h[k])
print(json.dumps({"ok": True}))
"""


@pytest.mark.slow
def test_model_sharding_auto_multi_device_lm():
    """ISSUE-8 acceptance: on a 2x4 forced-host-device mesh the "lm"
    component under model_sharding="auto" shards every model-parallel
    param 1/m per rank, matches "replicate" within fp32 tolerance with
    identical uplink accounting, and shrinks the per-device param +
    transient envelope toward the 1/m floor."""
    _run_forced_8dev(MODEL_SHARDING_SCRIPT)


@pytest.mark.slow
def test_replicate_11_mesh_stays_golden_with_8_devices():
    """The [1, 1] + model_sharding="replicate" path reproduces the
    pre-PR golden history float-exact even with 8 host devices visible
    (the auto machinery is inert unless opted into)."""
    golden = os.path.join(REPO, "tests", "golden",
                          "engine_history_pre_codec.json")
    _run_forced_8dev(GOLDEN_11_SCRIPT.replace("@GOLDEN@", repr(golden)))
