"""Robust rules x wire codecs: the composed seam (satellite of PR 9).

The robust aggregators consume dequantized payloads through the same
``(idx, val[, scale]) . (w * gscale)`` contract the streaming mean uses,
so every rule must compose with every lossy codec under attack. Pillars:

  (a) attacked quantized runs complete and move the model for
      {geometric_median, scalar_median} x {int8, fp8} under sign_flip —
      and the robust rule beats the plain mean's loss under the same
      attack at the same codec,
  (b) seed-determinism: an attacked quantized run replays bit-for-bit
      (history and params) under the same seed — stochastic rounding
      seeds, attack noise and the Byzantine cohort all come from seeded
      streams,
  (c) the codec is not a loophole: honest-cohort payload corruption by
      quantization stays small (robust rule output close to the
      uncompressed rule's output on the same round stream),
  (d) deterministic-rounding codecs (``stochastic=False``) are equally
      deterministic without consuming wire seeds.

Heavier grid points ride ``@pytest.mark.slow`` (run via ``-m slow``).
"""
import numpy as np
import pytest

import jax

from repro.fed import FLConfig, FLEngine

# --------------------------------------------------------------- fixtures


@pytest.fixture(scope="module")
def fcn_setup():
    from repro.configs import get_config
    from repro.data.synthetic import mixture_classification
    from repro.models.smallnets import (apply_fcn, classifier_loss,
                                        init_fcn)
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(1200, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg,
                                           b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=6, **flkw):
    from repro.fed import partition_label_skew
    params, x, y, loss_fn = fcn_setup
    parts = partition_label_skew(y, K, 3, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    flkw.setdefault("use_lbgm", True)
    flkw.setdefault("lbg_variant", "topk")
    flkw.setdefault("lbg_kw", {"k_frac": 0.1})
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             **flkw))


def run_rounds(fl, n=3, seed=0):
    rng = np.random.RandomState(seed)
    for _ in range(n):
        fl.run_round(rng)
    return fl


def assert_same_run(a, b):
    assert len(a.history) == len(b.history)
    for ra, rb in zip(a.history, b.history):
        for k in ra:
            assert ra[k] == rb[k], (k, ra[k], rb[k])
    for k in a.params:
        np.testing.assert_array_equal(np.asarray(a.params[k]),
                                      np.asarray(b.params[k]), err_msg=k)


ATTACKED = dict(attack="sign_flip", attack_frac=0.34,
                attack_kw={"scale": 4.0})


# ------------------------------------------------ (a) rule x codec grid


@pytest.mark.parametrize("agg,codec", [
    ("geometric_median", "int8"),
    ("scalar_median", "int8"),
    ("scalar_median", "fp8"),
], ids=["gm-int8", "med-int8", "med-fp8"])
def test_robust_rule_survives_attack_under_codec(fcn_setup, agg, codec):
    fl = run_rounds(make_engine(fcn_setup, aggregator=agg, codec=codec,
                                **ATTACKED))
    losses = [r["loss"] for r in fl.history]
    assert all(np.isfinite(l) for l in losses)
    assert fl.ledger.wire_bytes > 0
    # the model moved — quantized attacked rounds are not a no-op
    p0, _, _, _ = fcn_setup
    moved = any(
        not np.array_equal(np.asarray(fl.params[k]), np.asarray(p0[k]))
        for k in p0)
    assert moved


@pytest.mark.slow
@pytest.mark.parametrize("agg,codec", [
    ("geometric_median", "fp8"),
    ("trimmed_mean", "int8"),
    ("coordinate_median", "fp8"),
], ids=["gm-fp8", "tm-int8", "cm-fp8"])
def test_robust_rule_codec_grid_slow(fcn_setup, agg, codec):
    kw = {} if agg != "trimmed_mean" else {"aggregator_kw": {"beta": 0.2}}
    fl = run_rounds(make_engine(fcn_setup, aggregator=agg, codec=codec,
                                **ATTACKED, **kw), n=4)
    assert all(np.isfinite(r["loss"]) for r in fl.history)


@pytest.mark.slow
def test_robust_beats_mean_under_quantized_attack(fcn_setup):
    # same sign_flip cohort, same int8 wire: the geometric median should
    # end at a lower training loss than the poisoned plain mean
    mean = run_rounds(make_engine(fcn_setup, aggregator="mean",
                                  codec="int8", **ATTACKED), n=6)
    gm = run_rounds(make_engine(fcn_setup, aggregator="geometric_median",
                                codec="int8", **ATTACKED), n=6)
    assert gm.history[-1]["loss"] < mean.history[-1]["loss"]


# ------------------------------------------------- (b) seed determinism


@pytest.mark.parametrize("agg,codec", [("geometric_median", "int8"),
                                       ("scalar_median", "fp8")],
                         ids=["gm-int8", "med-fp8"])
def test_attacked_quantized_run_replays_exactly(fcn_setup, agg, codec):
    kw = dict(aggregator=agg, codec=codec, attack="gaussian",
              attack_frac=0.34, attack_kw={"sigma": 2.0},
              dropout_frac=0.2)
    a = run_rounds(make_engine(fcn_setup, **kw))
    b = run_rounds(make_engine(fcn_setup, **kw))
    assert_same_run(a, b)


def test_deterministic_rounding_needs_no_wire_seed(fcn_setup):
    kw = dict(aggregator="geometric_median", codec="int8",
              codec_kw={"stochastic": False}, **ATTACKED)
    a = run_rounds(make_engine(fcn_setup, **kw))
    b = run_rounds(make_engine(fcn_setup, **kw))
    assert_same_run(a, b)


# ------------------------------------- (c) quantization is not a loophole


def test_codec_error_small_on_honest_cohort(fcn_setup):
    # no attack: the robust rule over int8 wire should track the
    # uncompressed rule's history loss closely — quantization must not
    # look like an attack to the rule
    raw = run_rounds(make_engine(fcn_setup,
                                 aggregator="geometric_median"))
    q = run_rounds(make_engine(fcn_setup, aggregator="geometric_median",
                               codec="int8"))
    for rr, rq in zip(raw.history, q.history):
        np.testing.assert_allclose(rq["loss"], rr["loss"], rtol=0.1)
    # and the wire actually compressed relative to the fp32 codec
    assert 0 < q.ledger.wire_bytes < raw.ledger.wire_bytes
