"""Engine checkpoint/resume (``FLConfig.ckpt_every`` / ``--resume``).

Acceptance (ISSUE 10 tentpole):
  * ``save_checkpoint`` -> fresh engine -> ``restore_checkpoint`` ->
    continue is *bit-for-bit* the uninterrupted run — history, params,
    banks, comm ledger — on every scheduler (vmap, chunked, sharded,
    buffered with in-flight slots, and the topk-host store), through
    both the synchronous and prefetcher rng paths;
  * ``FLEngine.run(..., resume=True)`` and
    ``run_experiment(spec, resume=True)`` wire the same guarantee
    end-to-end (the CLI smoke lives in CI's slow job);
  * a checkpoint from a different config is refused.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_label_skew
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn


@pytest.fixture(scope="module")
def fcn_setup():
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(1200, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=8, **flkw):
    params, x, y, loss_fn = fcn_setup
    flkw.setdefault("use_lbgm", True)
    flkw.setdefault("lbg_variant", "topk")
    flkw.setdefault("lbg_kw", {"k_frac": 0.1})
    flkw.setdefault("delta_threshold", 0.5)
    parts = partition_label_skew(y, K, 3, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             chunk_size=4, **flkw))


def assert_same_run(fl_a, fl_b):
    assert len(fl_a.history) == len(fl_b.history)
    for ra, rb in zip(fl_a.history, fl_b.history):
        assert ra.keys() == rb.keys()
        for k in ra:
            assert ra[k] == rb[k], (k, ra[k], rb[k])
    for k in fl_a.params:
        np.testing.assert_array_equal(np.asarray(fl_a.params[k]),
                                      np.asarray(fl_b.params[k]), err_msg=k)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        fl_a.lbg, fl_b.lbg)
    assert fl_a.ledger.state_dict() == fl_b.ledger.state_dict()


SCHED_CASES = [
    ("vmap", {}),
    ("chunked", {}),
    ("chunked", {"lbg_variant": "topk-host"}),
    ("chunked", {"tiers": [4, 2], "codec": "int8"}),
    ("sharded", {"mesh": 1, "lbg_variant": "topk-sharded"}),
    ("buffered", {"latency": "straggler",
                  "latency_kw": {"frac": 0.5, "delay": 2, "jitter": 1,
                                 "max_staleness": 4}}),
]
SCHED_IDS = ["vmap", "chunked", "topk-host", "tiers-codec", "sharded",
             "buffered"]


@pytest.mark.parametrize("sched,extra", SCHED_CASES, ids=SCHED_IDS)
def test_save_restore_continue_bit_for_bit(fcn_setup, tmp_path, sched,
                                           extra):
    # uninterrupted 5 rounds (synchronous rng path)
    full = make_engine(fcn_setup, scheduler=sched, **extra)
    rng = np.random.RandomState(0)
    for _ in range(5):
        full.run_round(rng)
    # 3 rounds -> checkpoint -> FRESH engine -> restore -> 2 more
    part = make_engine(fcn_setup, scheduler=sched, **extra)
    rng = np.random.RandomState(0)
    for _ in range(3):
        part.run_round(rng)
    path = str(tmp_path / "ck.npz")
    part.save_checkpoint(path)
    res = make_engine(fcn_setup, scheduler=sched, **extra)
    rng2 = np.random.RandomState(777)   # overwritten by the restore
    assert res.restore_checkpoint(path, rng2) == 3
    for _ in range(2):
        res.run_round(rng2)
    assert_same_run(full, res)


def test_run_resume_prefetcher_path(fcn_setup, tmp_path):
    # engine.run uses the prefetcher: the checkpoint must carry the
    # producer-side rng snapshot, not the thread's read-ahead state
    path = str(tmp_path / "ck.npz")
    full = make_engine(fcn_setup, ckpt_every=2, ckpt_path=path)
    full.run(5)   # leaves a round-4 checkpoint behind
    res = make_engine(fcn_setup, ckpt_every=2, ckpt_path=path)
    res.run(5, resume=True)   # round 5 only
    assert_same_run(full, res)
    assert len(res.history) == 5


def test_buffered_inflight_slots_travel(fcn_setup, tmp_path):
    # payloads dispatched before the save must land after the resume
    kw = dict(scheduler="buffered", latency="fixed",
              latency_kw={"delay": 2})
    full = make_engine(fcn_setup, **kw)
    rng = np.random.RandomState(0)
    for _ in range(6):
        full.run_round(rng)
    part = make_engine(fcn_setup, **kw)
    rng = np.random.RandomState(0)
    for _ in range(2):   # save with every slot still in flight
        part.run_round(rng)
    path = str(tmp_path / "ck.npz")
    part.save_checkpoint(path)
    res = make_engine(fcn_setup, **kw)
    rng2 = np.random.RandomState(0)
    res.restore_checkpoint(path, rng2)
    # drop the replayed draws: restore rewinds rng to the saved stream
    for _ in range(4):
        res.run_round(rng2)
    assert_same_run(full, res)


def test_restore_rejects_mismatched_config(fcn_setup, tmp_path):
    path = str(tmp_path / "ck.npz")
    a = make_engine(fcn_setup)
    rng = np.random.RandomState(0)
    a.run_round(rng)
    a.save_checkpoint(path)
    b = make_engine(fcn_setup, delta_threshold=0.3)
    with pytest.raises(ValueError, match="config"):
        b.restore_checkpoint(path, np.random.RandomState(0))


def test_save_requires_round_boundary_state(fcn_setup, tmp_path):
    fl = make_engine(fcn_setup)
    with pytest.raises(ValueError):
        fl.save_checkpoint(str(tmp_path / "ck.npz"))  # no round run yet


def test_run_experiment_resume(tmp_path):
    from repro.fed.experiment import (ComponentSpec, EvalPolicy,
                                      ExperimentSpec, run_experiment)
    path = str(tmp_path / "ck.npz")

    def spec():
        return ExperimentSpec(
            name="resume-smoke",
            model=ComponentSpec("fcn"),
            data=ComponentSpec("mixture", {"n": 400, "n_eval": 100}),
            partition=ComponentSpec("label_skew",
                                    {"classes_per_client": 3}),
            fl=FLConfig(num_clients=4, tau=2, lr=0.05, batch_size=16,
                        use_lbgm=True, delta_threshold=0.2,
                        ckpt_every=2, ckpt_path=path),
            rounds=5,
            eval=EvalPolicy(every=0, final=True),
        )

    full = run_experiment(spec())
    run_experiment(spec(), rounds=3)        # ckpt at round 2
    res = run_experiment(spec(), resume=True)
    assert len(res.records) == 5
    for ra, rb in zip(full.records, res.records):
        assert ra.loss == rb.loss
        assert ra.uplink_floats == rb.uplink_floats
        assert ra.wire_bytes == rb.wire_bytes
    assert full.final_eval == res.final_eval
    with pytest.raises(ValueError, match="ckpt_path"):
        bad = spec()
        object.__setattr__(bad.fl, "ckpt_path", None)
        object.__setattr__(bad.fl, "ckpt_every", 0)
        run_experiment(bad, resume=True)
