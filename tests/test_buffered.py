"""Buffered async scheduler: equivalence, delivery accounting, replay.

Five pillars:
  (a) the acceptance gate — ``scheduler="buffered"`` with
      ``latency="none"`` and no dropout is bit-for-bit equal to
      ``"chunked"`` (history floats AND final params), including under
      partial sampling + dropout faults, and (slow) across robust rules
      and a lossy codec,
  (b) delivery-time accounting — with a fixed 1-round delay every wire
      byte lands in the arrival round (round 0 ships nothing), the
      delivered-payload count matches the host plan, and an undeliverable
      cohort (straggler ``drop=True``) never contributes bytes,
  (c) latency/staleness replay is seed-deterministic (same seed ->
      bit-identical history; different seed -> different delivery
      pattern) and the staleness discount is exactly 1.0 at s=0,
  (d) the host delivery plan's invariants: one in-flight slot per
      client, dispatch only when idle, stale = arrival - dispatch round,
  (e) satellite surfaces — FLConfig kw-key validation against component
      signatures, buffered-scheduler config rejections, the
      colluding_sign / adaptive_scaled attack components, and
      variable-tau compute heterogeneity.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.fed import FLConfig, FLEngine
from repro.fed.attacks import CSEED_KEY, STALE_KEY, make_attack
from repro.fed.latency import LATENCIES, NEVER
from repro.fed.registry import AGGREGATORS

# --------------------------------------------------------------- fixtures


@pytest.fixture(scope="module")
def fcn_setup():
    from repro.configs import get_config
    from repro.data.synthetic import mixture_classification
    from repro.models.smallnets import (apply_fcn, classifier_loss,
                                        init_fcn)
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(1200, 10, seed=0)
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg,
                                           b["x"], b["y"])
    return params, x, y, loss_fn


def make_engine(fcn_setup, K=6, **flkw):
    from repro.fed import partition_label_skew
    params, x, y, loss_fn = fcn_setup
    parts = partition_label_skew(y, K, 3, seed=0)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    flkw.setdefault("use_lbgm", True)
    flkw.setdefault("lbg_variant", "topk")
    flkw.setdefault("lbg_kw", {"k_frac": 0.1})
    flkw.setdefault("delta_threshold", 0.5)
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=K, tau=2, lr=0.05, batch_size=16,
                             **flkw))


def run_rounds(fl, n=3, seed=0):
    rng = np.random.RandomState(seed)
    for _ in range(n):
        fl.run_round(rng)
    return fl


def assert_same_run(a, b):
    assert len(a.history) == len(b.history)
    for ra, rb in zip(a.history, b.history):
        assert set(ra) == set(rb)
        for k in ra:
            assert ra[k] == rb[k], (k, ra[k], rb[k])
    for k in a.params:
        np.testing.assert_array_equal(np.asarray(a.params[k]),
                                      np.asarray(b.params[k]), err_msg=k)


# ------------------------------------------- (a) zero-latency equivalence


def test_zero_latency_bit_for_bit_chunked(fcn_setup):
    a = run_rounds(make_engine(fcn_setup, scheduler="chunked"))
    b = run_rounds(make_engine(fcn_setup, scheduler="buffered"))
    assert_same_run(a, b)


def test_zero_latency_with_sampling_and_dropout(fcn_setup):
    kw = dict(sample_frac=0.7, dropout_frac=0.25)
    a = run_rounds(make_engine(fcn_setup, scheduler="chunked", **kw), n=4)
    b = run_rounds(make_engine(fcn_setup, scheduler="buffered", **kw), n=4)
    assert_same_run(a, b)


def test_zero_latency_scalar_median(fcn_setup):
    kw = dict(aggregator="scalar_median")
    a = run_rounds(make_engine(fcn_setup, scheduler="chunked", **kw))
    b = run_rounds(make_engine(fcn_setup, scheduler="buffered", **kw))
    assert_same_run(a, b)


@pytest.mark.slow
@pytest.mark.parametrize("kw", [dict(aggregator="geometric_median"),
                                dict(codec="int8"),
                                dict(aggregator="geometric_median",
                                     codec="fp8"),
                                dict(attack="sign_flip", attack_frac=0.34,
                                     attack_kw={"scale": 4.0})],
                         ids=["gm", "int8", "gm-fp8", "attacked"])
def test_zero_latency_equivalence_matrix(fcn_setup, kw):
    a = run_rounds(make_engine(fcn_setup, scheduler="chunked", **kw), n=4)
    b = run_rounds(make_engine(fcn_setup, scheduler="buffered", **kw), n=4)
    assert_same_run(a, b)


# --------------------------------------- (b) delivery-time accounting


def test_wire_bytes_attributed_to_arrival_round(fcn_setup):
    # fixed delay=1 and one in-flight slot per client gives a period-2
    # cycle: dispatch at even rounds, delivery (and all wire bytes) at
    # odd rounds — round 0 ships nothing
    fl = run_rounds(make_engine(fcn_setup, scheduler="buffered",
                                latency="fixed",
                                latency_kw={"delay": 1}), n=5)
    wires = [r["wire_bytes"] for r in fl.history]
    assert fl.history[0]["uplink_floats"] == 0.0
    assert wires[0::2] == [0.0, 0.0, 0.0]
    assert all(w > 0 for w in wires[1::2])
    K = fl.cfg.num_clients
    assert fl.n_delivered == K * 2  # delivery rounds 1 and 3
    assert fl.ledger.wire_bytes == sum(wires)


def test_dropped_cohort_never_delivers(fcn_setup):
    fl = run_rounds(make_engine(
        fcn_setup, scheduler="buffered", latency="straggler",
        latency_kw={"frac": 0.5, "drop": True, "cohort": "head"}), n=4)
    K = fl.cfg.num_clients
    # head cohort [0, K/2) dispatches once and never delivers; the rest
    # deliver every round
    assert fl.n_delivered == (K // 2) * 4
    assert (fl._arrival[:K // 2] > 4).all()       # still in flight
    assert (fl._arrival[K // 2:] == -1).all()     # idle


# ----------------------------------------------- (c) replay determinism


def test_latency_replay_is_seed_deterministic(fcn_setup):
    kw = dict(scheduler="buffered", latency="lognormal",
              latency_kw={"scale": 1.0, "sigma": 0.75, "max_delay": 4},
              sample_frac=0.8, dropout_frac=0.1)
    a = run_rounds(make_engine(fcn_setup, **kw), n=5)
    b = run_rounds(make_engine(fcn_setup, **kw), n=5)
    assert_same_run(a, b)
    c = run_rounds(make_engine(fcn_setup, seed=7, **kw), n=5, seed=7)
    assert [r["wire_bytes"] for r in c.history] != \
        [r["wire_bytes"] for r in a.history]


def test_staleness_weight_exact_one_when_fresh():
    for name in LATENCIES.names():
        m = LATENCIES.get(name)()
        w = np.asarray(m.staleness_weight(jnp.zeros(3, jnp.float32)))
        assert (w == 1.0).all(), name
        # monotone non-increasing in staleness
        ws = np.asarray(m.staleness_weight(
            jnp.arange(5, dtype=jnp.float32)))
        assert (np.diff(ws) <= 0).all(), name


# ------------------------------------------------- (d) host plan logic


def test_delivery_plan_one_in_flight_slot(fcn_setup):
    fl = make_engine(fcn_setup, scheduler="buffered", latency="fixed",
                     latency_kw={"delay": 2})
    rng = np.random.RandomState(0)
    plans = []
    for _ in range(6):
        fl._sample_batches(rng)
        plans.append(fl._sample_mask(rng))
    # round 0: everyone idle -> all dispatch, nothing delivers
    assert plans[0]["dispatch"].all() and not plans[0]["deliver"].any()
    # rounds 1: all in flight -> no dispatch, no delivery yet
    assert not plans[1]["dispatch"].any()
    assert not plans[1]["deliver"].any()
    # round 2: delay-2 payloads land, stale == 2; dispatch is gated on
    # the slot being idle *at the top of the round*, so the re-dispatch
    # happens one round after delivery
    assert plans[2]["deliver"].all()
    assert (plans[2]["stale"] == 2.0).all()
    assert not plans[2]["dispatch"].any()
    assert plans[3]["dispatch"].all() and not plans[3]["deliver"].any()
    # never dispatch while a payload is in flight
    in_flight = np.zeros(fl.cfg.num_clients, bool)
    for p in plans:
        assert not (p["dispatch"].astype(bool) & in_flight).any()
        in_flight |= p["dispatch"].astype(bool)
        in_flight &= ~p["deliver"].astype(bool)


def test_latency_model_sample_shapes():
    for name in LATENCIES.names():
        m = LATENCIES.get(name)()
        m.setup(8, seed=0)
        d = m.sample_delays(np.random.RandomState(0), 8)
        assert d.shape == (8,) and d.dtype.kind == "i" and (d >= 0).all()


def test_straggler_drop_uses_never_sentinel():
    m = LATENCIES.get("straggler")(frac=0.5, drop=True, cohort="head")
    m.setup(4, seed=0)
    d = m.sample_delays(np.random.RandomState(0), 4)
    assert list(d) == [NEVER, NEVER, 0, 0]


# --------------------------------------------- (e) satellite surfaces


@pytest.mark.parametrize("kwargs,frag", [
    (dict(attack="gaussian", attack_frac=0.2, attack_kw={"sgima": 2.0}),
     "sigma"),
    (dict(aggregator="geometric_median", aggregator_kw={"iter": 5}),
     "iters"),
    (dict(codec="int8", codec_kw={"stochastc": False}), "stochastic"),
    (dict(scheduler="buffered", use_lbgm=True, lbg_variant="topk",
          lbg_kw={"k_frac": 0.1}, latency="straggler",
          latency_kw={"fraction": 0.2}), "frac"),
], ids=["attack", "aggregator", "codec", "latency"])
def test_kw_keys_validated_at_construction(kwargs, frag):
    with pytest.raises(ValueError, match="valid keys") as exc:
        FLConfig(**kwargs)
    assert frag in str(exc.value)


def test_kw_validation_accepts_valid_keys():
    FLConfig(aggregator="geometric_median", aggregator_kw={"iters": 4})
    FLConfig(attack="gaussian", attack_frac=0.2, attack_kw={"sigma": 2.0})
    FLConfig(scheduler="buffered", use_lbgm=True, lbg_variant="topk",
             lbg_kw={"k_frac": 0.1}, latency="straggler",
             latency_kw={"frac": 0.2, "delay": 3, "alpha": 1.0})


def test_valid_kw_introspection():
    assert AGGREGATORS.valid_kw("geometric_median") == {"iters", "eps"}
    assert AGGREGATORS.valid_kw("mean") == frozenset()
    assert LATENCIES.valid_kw("straggler") >= {"frac", "delay", "drop"}


@pytest.mark.parametrize("kwargs", [
    dict(scheduler="buffered", use_lbgm=True, lbg_variant="dense"),
    dict(scheduler="buffered", use_lbgm=False),
    dict(scheduler="buffered", use_lbgm=True, lbg_variant="topk",
         fused_kernels=False),
    dict(scheduler="chunked", latency="fixed"),
    dict(latency="nope"),
], ids=["dense-bank", "no-lbgm", "no-fused", "latency-needs-buffered",
        "unknown-latency"])
def test_buffered_config_rejections(kwargs):
    with pytest.raises(ValueError, match="FLConfig"):
        FLConfig(**kwargs)


def _toy_asg(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(5), jnp.float32)}


def test_colluding_sign_shares_one_direction():
    atk = make_attack(FLConfig(attack="colluding_sign", attack_frac=0.5,
                               attack_kw={"scale": 2.0}))
    extras = {CSEED_KEY: jnp.uint32(123)}
    a = atk._corrupt(_toy_asg(0), extras)
    b = atk._corrupt(_toy_asg(1), extras)
    # both clients' corrupted updates are parallel (same unit direction,
    # scaled by each client's own norm)
    va = np.concatenate([np.asarray(a[k]).ravel() for k in sorted(a)])
    vb = np.concatenate([np.asarray(b[k]).ravel() for k in sorted(b)])
    cos = va @ vb / (np.linalg.norm(va) * np.linalg.norm(vb))
    np.testing.assert_allclose(cos, 1.0, atol=1e-5)
    # magnitude = scale * ||g||
    g = _toy_asg(0)
    gn = np.sqrt(sum(float(jnp.sum(jnp.square(x))) for x in g.values()))
    np.testing.assert_allclose(np.linalg.norm(va), 2.0 * gn, rtol=1e-5)
    # a different round seed picks a different direction
    c = atk._corrupt(_toy_asg(0), {CSEED_KEY: jnp.uint32(124)})
    vc = np.concatenate([np.asarray(c[k]).ravel() for k in sorted(c)])
    assert abs(va @ vc / (np.linalg.norm(va) * np.linalg.norm(vc))) < 0.9


def test_adaptive_scaled_cancels_staleness_discount():
    atk = make_attack(FLConfig(attack="adaptive_scaled", attack_frac=0.5,
                               attack_kw={"scale": 3.0, "alpha": 0.5}))
    g = _toy_asg(0)
    fresh = atk._corrupt(g, {})
    for k in g:
        np.testing.assert_allclose(np.asarray(fresh[k]),
                                   -3.0 * np.asarray(g[k]), rtol=1e-6)
    stale = atk._corrupt(g, {STALE_KEY: jnp.float32(3.0)})
    for k in g:
        np.testing.assert_allclose(
            np.asarray(stale[k]),
            -3.0 * 2.0 * np.asarray(g[k]), rtol=1e-5)  # (1+3)^0.5 = 2


def test_variable_tau_heterogeneity(fcn_setup):
    base = dict(scheduler="buffered", latency="straggler")
    # slow_tau == tau: the masked scan is a no-op mask -> histories agree
    a = run_rounds(make_engine(fcn_setup, **base,
                               latency_kw={"frac": 0.5, "delay": 1}))
    b = run_rounds(make_engine(fcn_setup, **base,
                               latency_kw={"frac": 0.5, "delay": 1,
                                           "slow_tau": 2}))
    for ra, rb in zip(a.history, b.history):
        np.testing.assert_allclose(ra["loss"], rb["loss"], rtol=1e-5)
    # slow_tau < tau changes the slow cohort's updates
    c = run_rounds(make_engine(fcn_setup, **base,
                               latency_kw={"frac": 0.5, "delay": 1,
                                           "slow_tau": 1}))
    assert [r["loss"] for r in c.history] != [r["loss"] for r in a.history]
    # and is itself seed-deterministic
    d = run_rounds(make_engine(fcn_setup, **base,
                               latency_kw={"frac": 0.5, "delay": 1,
                                           "slow_tau": 1}))
    assert_same_run(c, d)


def test_buffered_spec_json_round_trip():
    cfg = FLConfig(scheduler="buffered", use_lbgm=True,
                   lbg_variant="topk",
                   lbg_kw={"k_frac": 0.1}, latency="straggler",
                   latency_kw={"frac": 0.2, "delay": 4},
                   aggregator="geometric_median")
    assert FLConfig.from_dict(cfg.to_dict()) == cfg


# ----------------------------------------------- max-staleness eviction


def test_max_staleness_accepted_by_every_model():
    for model, kw in (("none", {}), ("fixed", {"delay": 1}),
                      ("uniform", {"low": 0, "high": 2}),
                      ("lognormal", {"scale": 1.0}),
                      ("straggler", {"frac": 0.5})):
        FLConfig(scheduler="buffered", use_lbgm=True, lbg_variant="topk",
                 latency=model, latency_kw={**kw, "max_staleness": 3})
    # the value check lives in the model constructor (FLConfig only
    # validates key names) -> surfaces when the engine builds the model
    from repro.fed.latency import make_latency
    with pytest.raises(ValueError, match="max_staleness"):
        make_latency(FLConfig(scheduler="buffered", use_lbgm=True,
                              lbg_variant="topk", latency="fixed",
                              latency_kw={"max_staleness": -1}))


def test_eviction_unpins_dropped_payloads(fcn_setup):
    # drop=True parks the slow cohort's payloads at delay=NEVER — without
    # eviction those slots are pinned forever and n_evicted stays 0
    base = dict(scheduler="buffered", latency="straggler")
    pinned = run_rounds(make_engine(
        fcn_setup, **base,
        latency_kw={"frac": 0.5, "drop": True, "cohort": "head"}), n=6)
    assert pinned.ledger.n_evicted == 0
    evict = run_rounds(make_engine(
        fcn_setup, **base,
        latency_kw={"frac": 0.5, "drop": True, "cohort": "head",
                    "max_staleness": 2}), n=6)
    # cohort of 3 (K=6, frac .5): each eviction frees the slot to
    # re-dispatch, so the counter keeps growing past one sweep
    assert evict.ledger.n_evicted > 0
    assert evict.ledger.summary()["n_evicted"] == evict.ledger.n_evicted
    # the freed slots re-enter training: histories must diverge
    assert [r["loss"] for r in evict.history] != \
        [r["loss"] for r in pinned.history]


def test_generous_max_staleness_is_a_no_op(fcn_setup):
    # delay=1 payloads are at most 1 round stale: a bound of 5 never
    # triggers, so the run stays bit-for-bit the unbounded one
    base = dict(scheduler="buffered", latency="fixed")
    a = run_rounds(make_engine(fcn_setup, **base,
                               latency_kw={"delay": 1}), n=4)
    b = run_rounds(make_engine(fcn_setup, **base,
                               latency_kw={"delay": 1,
                                           "max_staleness": 5}), n=4)
    assert_same_run(a, b)
    assert b.ledger.n_evicted == 0
    assert "n_evicted" not in b.ledger.summary()


def test_eviction_counts_are_exact(fcn_setup):
    # fixed delay 3 with bound 1: every dispatched payload ages out at
    # staleness 2 before its round-3 arrival — nothing ever delivers,
    # and each client re-dispatches the round after its eviction
    fl = run_rounds(make_engine(fcn_setup, K=6,
                                scheduler="buffered", latency="fixed",
                                latency_kw={"delay": 3,
                                            "max_staleness": 1}), n=8)
    per_round = [h.get("n_delivered", None) for h in fl.history]
    # dispatch at t, evicted at t+2, re-dispatch at t+2: 6 clients evict
    # every other round from round 3 on -> 3 sweeps in 8 rounds
    assert fl.ledger.n_evicted == 18
    assert all(not d for d in per_round if d is not None)
