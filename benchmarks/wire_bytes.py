"""Wire codec benchmark: real bytes-per-round and accuracy vs codec.

The question this grid answers is the one ``repro.comm.wire`` exists
for: how many *bytes* does a round actually ship once the payload is
encoded, and what does quantization cost in accuracy? Each row is one
cell of

    {dense FedAvg, LBGM scalar rounds} x {none, int8, fp8}

with the measured bytes/round as the row value (NOT a time — flagged in
``derived``) and final held-out accuracy in the metadata, written to
BENCH_engine.json so byte trajectories across revisions are diffable
the same way the perf rows are.

Regimes (the fig5 FCN config, as in the robustness grid):

* ``dense``  — ``use_lbgm=False``: plain FedAvg; quantized codecs encode
  the dense update (1 byte/param + one fp32 scale per leaf).
* ``scalar`` — LBGM with the top-k store and ``delta_threshold=0.9``:
  after the round-0 refresh most rounds recycle (1-byte e4m3 rho on the
  wire for quantized codecs, 4-byte fp32 for ``none``); full rounds ship
  the sparse payload (values at the codec's width + varint-delta
  indices vs raw 4-byte ones for ``none``).

The headline cell (the PR's acceptance gate): in the ``scalar`` regime,
``int8`` must cut total wire bytes by >= ``MIN_RATIO`` (3x) *on top of*
LBGM's fp32 wire while staying within ``ACC_TOL`` of the fp32 run's
final accuracy — compression stacking on recycling, not replacing it.
"""
from __future__ import annotations

from benchmarks.common import build_spec, record_bench, spec_metadata

#: acceptance: int8 total wire bytes vs codec="none" in the scalar regime
MIN_RATIO = 3.0
#: and its final accuracy must stay within this of the fp32 run
ACC_TOL = 0.03

CODECS = ("none", "int8", "fp8")


def _cell(regime: str, codec: str, rounds: int, num_clients: int,
          n_data: int, delta: float = 0.9) -> dict:
    """Run one grid cell; returns byte + accuracy measurements."""
    import numpy as np

    from repro.fed import run_experiment

    flkw = dict(codec=codec, sample_frac=1.0)
    if regime == "scalar":
        flkw.update(use_lbgm=True, lbg_variant="topk",
                    lbg_kw={"k_frac": 0.1}, delta_threshold=delta)
    else:
        flkw.update(use_lbgm=False)
    spec = build_spec(num_clients=num_clients, n_data=n_data,
                      n_eval=max(200, n_data // 4),
                      name=f"wire-{regime}-{codec}", **flkw)
    result = run_experiment(spec, rounds)
    last = result.records[-1]
    return {
        "test_acc": float(result.final_eval["test_acc"]),
        "frac_scalar": float(np.mean([r.frac_scalar
                                      for r in result.records])),
        "total_wire_bytes": float(last.total_wire_bytes),
        "bytes_per_round": float(last.total_wire_bytes) / rounds,
        "wire_savings": float(last.wire_savings),
        "spec": spec,
    }


def _emit_bytes(name: str, cell: dict, base: dict, **meta) -> None:
    """Bytes row: CSV + BENCH_engine.json, value flagged as bytes."""
    bpr = cell["bytes_per_round"]
    ratio = base["total_wire_bytes"] / max(cell["total_wire_bytes"], 1.0)
    derived = (f"bytes_per_round={bpr:.0f} ratio_vs_none={ratio:.2f} "
               f"test_acc={cell['test_acc']:.3f} "
               f"wire_savings={cell['wire_savings']:.3f} "
               f"frac_scalar={cell['frac_scalar']:.2f} (row value is "
               "bytes/round, not a time)")
    print(f"{name},{bpr:.0f},{derived}")
    record_bench(name, bpr, {
        "derived": derived, "bytes_per_round": bpr,
        "total_wire_bytes": cell["total_wire_bytes"],
        "ratio_vs_none": ratio, "test_acc": cell["test_acc"],
        "acc_gap_vs_none": base["test_acc"] - cell["test_acc"],
        "wire_savings": cell["wire_savings"],
        "frac_scalar": cell["frac_scalar"], **meta,
        **spec_metadata(cell["spec"]),
    })


def run(rounds: int = 25, num_clients: int = 20, n_data: int = 2000,
        codecs=CODECS, delta: float = 0.9) -> None:
    for regime in ("dense", "scalar"):
        cells = {}
        for codec in codecs:
            cells[codec] = _cell(regime, codec, rounds=rounds,
                                 num_clients=num_clients, n_data=n_data,
                                 delta=delta)
            _emit_bytes(f"wire_bytes/{regime}/{codec}", cells[codec],
                        cells.get("none", cells[codec]), regime=regime)
        if regime == "scalar":
            _headline(cells)


def _headline(cells: dict) -> None:
    """The acceptance summary row: int8 >= MIN_RATIO x fewer wire bytes
    than fp32 LBGM at <= ACC_TOL accuracy gap. Skipped (with a note) if
    the grid didn't include both cells."""
    if "none" not in cells or "int8" not in cells:
        print("wire_bytes/scalar/headline,nan,skipped "
              "(none/int8 not both in grid)")
        return
    none, int8 = cells["none"], cells["int8"]
    ratio = none["total_wire_bytes"] / max(int8["total_wire_bytes"], 1.0)
    gap = none["test_acc"] - int8["test_acc"]
    ok = ratio >= MIN_RATIO and abs(gap) <= ACC_TOL
    derived = (f"int8 vs fp32 LBGM: byte_ratio={ratio:.2f} "
               f"(>= {MIN_RATIO}), acc_gap={gap:+.3f} (|.| <= {ACC_TOL}) "
               f"-> {'PASS' if ok else 'FAIL'} (row value is the byte "
               "ratio, not a time)")
    name = "wire_bytes/scalar/headline"
    print(f"{name},{ratio:.2f},{derived}")
    record_bench(name, ratio, {
        "derived": derived, "byte_ratio": ratio, "acc_gap": gap,
        "min_ratio": MIN_RATIO, "acc_tol": ACC_TOL, "pass": ok,
        "none_bytes_per_round": none["bytes_per_round"],
        "int8_bytes_per_round": int8["bytes_per_round"],
    })


if __name__ == "__main__":
    import benchmarks  # noqa: F401  (src/ path bootstrap)
    run()
