"""Byzantine-robustness benchmark: accuracy vs attack fraction.

The question this grid answers is the one ``repro.fed.robust`` exists
for: does LBGM's scalar-round compression change how much damage a
Byzantine cohort does, and does a robust server rule recover it? Each
row is final held-out accuracy (NOT a time — the ``us_per_round`` field
carries the accuracy, flagged in ``derived``) for one cell of

    {dense FedAvg, LBGM scalar rounds} x {mean, geometric_median}
        x {clean, sign_flip, gaussian} x attack fraction

written to BENCH_engine.json so robustness trajectories across revisions
are diffable the same way the perf rows are.

Regimes:

* ``dense``  — ``use_lbgm=False``: plain FedAvg, every client uploads a
  dense update; robust rules see the raw per-client vectors.
* ``scalar`` — LBGM with the top-k store and ``delta_threshold=0.9``:
  after the round-0 refresh ~90% of rounds recycle, so the server
  aggregates the sparse (idx, val) scalar-round payloads (each row
  records the measured ``frac_scalar``). Attacks corrupt the client
  payload BEFORE the LBG pipeline (see ``fed/attacks``), so a flipped
  update also poisons the attacker's rho on recycle rounds — the regime
  the paper never studies.

The headline cell (the PR's acceptance gate): at a 20% sign-flip cohort
(``scale=4`` — flip and amplify, the standard reverse-gradient attack),
plain-mean accuracy collapses in BOTH regimes while the geometric median
stays within ``GM_TOL`` of the clean run; the per-regime ``headline``
summary row asserts exactly that and records both gaps.
"""
from __future__ import annotations

from typing import Optional

from benchmarks.common import build_spec, record_bench, spec_metadata

#: acceptance tolerance: geometric median must stay within this much of
#: the clean-run accuracy at the headline 20% sign-flip cell
GM_TOL = 0.05
#: and plain mean must lose at least this much accuracy vs clean there
MEAN_MIN_DROP = 0.20

#: the attack grid: (attack registry key, attack_kw). The last two are
#: the PR-9 coordinated/adaptive attacks: colluding_sign aims the whole
#: cohort's mass down one shared random direction (the case independent
#: flips under-sell), adaptive_scaled amplifies the flipped update —
#: and, under the buffered scheduler, pre-compensates the server's
#: staleness discount.
ATTACKS = (("sign_flip", {"scale": 4.0}), ("gaussian", {"sigma": 2.0}),
           ("colluding_sign", {"scale": 4.0}),
           ("adaptive_scaled", {"scale": 4.0}))


def _cell(regime: str, agg: str, rounds: int, num_clients: int,
          n_data: int, attack: Optional[str] = None,
          attack_frac: float = 0.0, attack_kw: Optional[dict] = None,
          delta: float = 0.9) -> dict:
    """Run one grid cell; returns {test_acc, frac_scalar, spec}."""
    import numpy as np

    from repro.fed import run_experiment

    flkw = dict(aggregator=agg, attack=attack, attack_frac=attack_frac,
                attack_kw=attack_kw, sample_frac=1.0)
    if regime == "scalar":
        flkw.update(use_lbgm=True, lbg_variant="topk",
                    lbg_kw={"k_frac": 0.1}, delta_threshold=delta)
    else:
        flkw.update(use_lbgm=False)
    tag = "clean" if attack is None else f"{attack}-f{attack_frac}"
    spec = build_spec(num_clients=num_clients, n_data=n_data,
                      n_eval=max(200, n_data // 4),
                      name=f"robust-{regime}-{agg}-{tag}", **flkw)
    result = run_experiment(spec, rounds)
    return {
        "test_acc": float(result.final_eval["test_acc"]),
        "frac_scalar": float(np.mean([r.frac_scalar
                                      for r in result.records])),
        "spec": spec,
    }


def _emit_acc(name: str, cell: dict, clean_acc: float, **meta) -> None:
    """Accuracy row: CSV + BENCH_engine.json, value flagged as accuracy."""
    acc = cell["test_acc"]
    derived = (f"test_acc={acc:.3f} acc_drop_vs_clean="
               f"{clean_acc - acc:+.3f} frac_scalar="
               f"{cell['frac_scalar']:.2f} (row value is accuracy, "
               "not a time)")
    print(f"{name},{acc:.3f},{derived}")
    record_bench(name, acc, {
        "derived": derived, "test_acc": acc, "clean_acc": clean_acc,
        "acc_drop_vs_clean": clean_acc - acc,
        "frac_scalar": cell["frac_scalar"], **meta,
        **spec_metadata(cell["spec"]),
    })


def run(rounds: int = 25, num_clients: int = 20, n_data: int = 2000,
        fracs=(0.2, 0.4), attacks=ATTACKS, headline_frac: float = 0.2,
        delta: float = 0.9) -> None:
    for regime in ("dense", "scalar"):
        clean, attacked = {}, {}
        for agg in ("mean", "geometric_median"):
            kw = dict(rounds=rounds, num_clients=num_clients,
                      n_data=n_data, delta=delta)
            clean[agg] = _cell(regime, agg, **kw)
            _emit_acc(f"robustness/{regime}/{agg}/clean", clean[agg],
                      clean[agg]["test_acc"], regime=regime,
                      aggregator=agg, attack=None, attack_frac=0.0)
            for attack, attack_kw in attacks:
                for frac in fracs:
                    cell = _cell(regime, agg, attack=attack,
                                 attack_frac=frac, attack_kw=attack_kw,
                                 **kw)
                    attacked[(agg, attack, frac)] = cell
                    _emit_acc(
                        f"robustness/{regime}/{agg}/{attack}/frac{frac}",
                        cell, clean[agg]["test_acc"], regime=regime,
                        aggregator=agg, attack=attack, attack_frac=frac,
                        attack_kw=dict(attack_kw))
        _headline(regime, clean, attacked, headline_frac)


def _headline(regime: str, clean: dict, attacked: dict,
              frac: float) -> None:
    """The acceptance summary row for one regime: at a >=20% sign-flip
    cohort, gm holds within GM_TOL of clean while mean drops >=
    MEAN_MIN_DROP. Skipped (with a note) if the grid didn't include the
    headline cell."""
    key_m, key_g = ("mean", "sign_flip", frac), \
        ("geometric_median", "sign_flip", frac)
    if key_m not in attacked or key_g not in attacked:
        print(f"robustness/{regime}/headline,nan,skipped "
              f"(sign_flip frac={frac} not in grid)")
        return
    mean_drop = clean["mean"]["test_acc"] - attacked[key_m]["test_acc"]
    gm_gap = (clean["geometric_median"]["test_acc"]
              - attacked[key_g]["test_acc"])
    ok = gm_gap <= GM_TOL and mean_drop >= MEAN_MIN_DROP
    derived = (f"sign_flip frac={frac}: mean_drop={mean_drop:.3f} "
               f"(>= {MEAN_MIN_DROP}), gm_gap={gm_gap:.3f} "
               f"(<= {GM_TOL}) -> {'PASS' if ok else 'FAIL'} "
               "(row value is the mean's accuracy drop, not a time)")
    name = f"robustness/{regime}/headline"
    print(f"{name},{mean_drop:.3f},{derived}")
    record_bench(name, mean_drop, {
        "derived": derived, "regime": regime, "attack": "sign_flip",
        "attack_frac": frac, "mean_drop": mean_drop, "gm_gap": gm_gap,
        "gm_tol": GM_TOL, "mean_min_drop": MEAN_MIN_DROP, "pass": ok,
    })


if __name__ == "__main__":
    import benchmarks  # noqa: F401  (src/ path bootstrap)
    run()
