"""Async heterogeneity benchmark: stragglers as latency vs stragglers
as dropout.

The question the ``"buffered"`` scheduler exists for: when a cohort of
slow clients can't make the round deadline, does treating them as
*latency* (FedBuff-style buffered aggregation with a staleness discount,
``repro.fed.latency``) recover the accuracy that treating them as
*dropout* forfeits? Each row is final held-out accuracy (NOT a time —
the ``us_per_round`` field carries the accuracy, flagged in ``derived``)
for one cell of

    {clean, dropout, buffered} x straggler fraction x latency delay
        x {mean, geometric_median}

written to BENCH_engine.json so the trajectory is diffable across
revisions, same as the robustness grid.

Arms (all three run the same LBGM top-k pipeline so the only variable is
what happens to the straggler cohort):

* ``clean``    — synchronous ``"chunked"``: every client delivers every
  round; the accuracy upper bound.
* ``dropout``  — ``"buffered"`` with ``straggler(drop=True)``: the
  cohort dispatches once and its payload never arrives — exactly the
  deadline-based protocol that forfeits the stragglers' data. The grid
  runs ``classes_per_client=1`` (each client holds one class's shard)
  with ``cohort="head"``: at the default seed the head cohort is the
  SOLE owner of one class's entire training pool, so dropping it makes
  that class unlearnable — a durable accuracy gap rather than a
  transient convergence-speed one.
* ``buffered`` — ``"buffered"`` with ``straggler(delay=d)``: the same
  cohort delivers ``d`` rounds late, folded in at arrival with the
  ``1/(1+s)**alpha`` staleness discount.

The headline cell (the PR's acceptance gate): at a 20% straggler cohort,
buffered aggregation under the **mean** recovers at least
``RECOVER_MIN`` of the accuracy gap dropout opens against the clean run:

    acc_buf - acc_drop >= RECOVER_MIN * (acc_clean - acc_drop)

The ``async/mean/headline`` row asserts exactly that and records all
three accuracies. ``MIN_GAP`` guards the claim against a vacuous
denominator: if dropout costs almost nothing the cell is reported as
skipped rather than trivially passed.

Robust rules get an informational ``suppression`` row instead of the
acceptance gate, because the measured interaction is the opposite and
it is *structural*, not a bug: a weighted geometric median treats the
straggler cohort — a 20% minority, further down-weighted by the
staleness discount, pushing a direction (its sole class) the 80%
majority's updates don't support — exactly like the Byzantine minority
it exists to suppress. Delivered straggler payloads shift the gm
output by ~1e-2 in parameter space and recover none of the dropout
gap. The row records recovered/gap so the trajectory catches any
future rule (e.g. staleness-aware trimming inside the rule, or
server-side momentum) that resolves the tension.
"""
from __future__ import annotations

from benchmarks.common import build_spec, record_bench, spec_metadata

#: acceptance: buffered must recover at least this fraction of the
#: clean-vs-dropout accuracy gap at the headline cell
RECOVER_MIN = 0.5
#: and the dropout gap itself must be at least this large for the
#: recovery claim to be non-vacuous
MIN_GAP = 0.03


def _cell(arm: str, agg: str, rounds: int, num_clients: int, n_data: int,
          frac: float = 0.2, delay: int = 4, alpha: float = 0.5,
          delta: float = 0.5, classes_per_client: int = 1) -> dict:
    """Run one grid cell; returns {test_acc, frac_scalar, spec}."""
    import numpy as np

    from repro.fed import run_experiment

    flkw = dict(aggregator=agg, sample_frac=1.0, use_lbgm=True,
                lbg_variant="topk", lbg_kw={"k_frac": 0.1},
                delta_threshold=delta)
    if arm == "clean":
        flkw.update(scheduler="chunked")
    elif arm == "dropout":
        flkw.update(scheduler="buffered", latency="straggler",
                    latency_kw={"frac": frac, "drop": True,
                                "cohort": "head", "alpha": alpha})
    elif arm == "buffered":
        flkw.update(scheduler="buffered", latency="straggler",
                    latency_kw={"frac": frac, "delay": delay,
                                "cohort": "head", "alpha": alpha})
    else:
        raise ValueError(f"unknown arm {arm!r}")
    tag = "clean" if arm == "clean" else f"{arm}-f{frac}"
    spec = build_spec(num_clients=num_clients, n_data=n_data,
                      n_eval=max(200, n_data // 4),
                      classes_per_client=classes_per_client,
                      name=f"async-{arm}-{agg}-{tag}", **flkw)
    result = run_experiment(spec, rounds)
    return {
        "test_acc": float(result.final_eval["test_acc"]),
        "frac_scalar": float(np.mean([r.frac_scalar
                                      for r in result.records])),
        "spec": spec,
    }


def _emit_acc(name: str, cell: dict, clean_acc: float, **meta) -> None:
    """Accuracy row: CSV + BENCH_engine.json, value flagged as accuracy."""
    acc = cell["test_acc"]
    derived = (f"test_acc={acc:.3f} acc_drop_vs_clean="
               f"{clean_acc - acc:+.3f} frac_scalar="
               f"{cell['frac_scalar']:.2f} (row value is accuracy, "
               "not a time)")
    print(f"{name},{acc:.3f},{derived}")
    record_bench(name, acc, {
        "derived": derived, "test_acc": acc, "clean_acc": clean_acc,
        "acc_drop_vs_clean": clean_acc - acc, **meta,
        **spec_metadata(cell["spec"]),
    })


def run(rounds: int = 40, num_clients: int = 20, n_data: int = 2000,
        fracs=(0.2, 0.4), delays=(4,),
        aggs=("mean", "geometric_median"), headline_frac: float = 0.2,
        alpha: float = 0.5) -> None:
    headline_delay = delays[0]
    for agg in aggs:
        kw = dict(agg=agg, rounds=rounds, num_clients=num_clients,
                  n_data=n_data, alpha=alpha)
        clean = _cell("clean", **kw)
        _emit_acc(f"async/{agg}/clean", clean, clean["test_acc"],
                  arm="clean", straggler_frac=0.0)
        cells = {}
        for frac in fracs:
            drop = _cell("dropout", frac=frac, **kw)
            cells[("dropout", frac, None)] = drop
            _emit_acc(f"async/{agg}/dropout/frac{frac}", drop,
                      clean["test_acc"], arm="dropout",
                      straggler_frac=frac)
            for delay in delays:
                buf = _cell("buffered", frac=frac, delay=delay, **kw)
                cells[("buffered", frac, delay)] = buf
                _emit_acc(f"async/{agg}/buffered/frac{frac}/d{delay}",
                          buf, clean["test_acc"], arm="buffered",
                          straggler_frac=frac, delay=delay)
        _headline(agg, clean, cells, headline_frac, headline_delay)


def _headline(agg: str, clean: dict, cells: dict, frac: float,
              delay: int) -> None:
    """The summary row for one aggregator at the headline straggler
    fraction. For the mean it is the acceptance gate (buffered recovers
    >= RECOVER_MIN of the accuracy dropout forfeits); for robust rules
    it is the informational ``suppression`` row documenting how much of
    the late minority's contribution the rule admits (see the module
    docstring — a gm suppressing the stale minority is the structurally
    expected outcome, not a failure). Skipped (with a note) if the grid
    didn't include the headline cell or the dropout gap is too small to
    support the claim."""
    gate = agg == "mean"
    key_d, key_b = ("dropout", frac, None), ("buffered", frac, delay)
    name = f"async/{agg}/{'headline' if gate else 'suppression'}"
    if key_d not in cells or key_b not in cells:
        print(f"{name},nan,skipped (frac={frac} d={delay} not in grid)")
        return
    acc_c = clean["test_acc"]
    acc_d = cells[key_d]["test_acc"]
    acc_b = cells[key_b]["test_acc"]
    gap = acc_c - acc_d
    recovered = acc_b - acc_d
    if gap < MIN_GAP:
        derived = (f"frac={frac} d={delay}: dropout gap {gap:.3f} < "
                   f"MIN_GAP={MIN_GAP} — recovery claim vacuous, SKIP")
        print(f"{name},nan,{derived}")
        record_bench(name, float("nan"), {
            "derived": derived, "aggregator": agg, "straggler_frac": frac,
            "delay": delay, "clean_acc": acc_c, "dropout_acc": acc_d,
            "buffered_acc": acc_b, "gap": gap, "pass": None,
        })
        return
    meta = {
        "aggregator": agg, "straggler_frac": frac, "delay": delay,
        "clean_acc": acc_c, "dropout_acc": acc_d, "buffered_acc": acc_b,
        "gap": gap, "recovered": recovered, "recover_min": RECOVER_MIN,
        "min_gap": MIN_GAP,
    }
    accs = (f"clean={acc_c:.3f} dropout={acc_d:.3f} "
            f"buffered={acc_b:.3f} recovered={recovered:.3f} "
            f"of gap={gap:.3f}")
    if gate:
        ok = recovered >= RECOVER_MIN * gap
        derived = (f"frac={frac} d={delay}: {accs} "
                   f"(need >= {RECOVER_MIN:.0%}) -> "
                   f"{'PASS' if ok else 'FAIL'} "
                   "(row value is the recovered accuracy, not a time)")
        meta["pass"] = ok
    else:
        derived = (f"frac={frac} d={delay}: {accs} — informational: "
                   "the robust rule's admission of the stale minority "
                   "(no acceptance semantics; see module docstring) "
                   "(row value is the recovered accuracy, not a time)")
    print(f"{name},{recovered:.3f},{derived}")
    record_bench(name, recovered, {"derived": derived, **meta})


if __name__ == "__main__":
    import benchmarks  # noqa: F401  (src/ path bootstrap)
    run()
