"""Cohort-scaling benchmark: ms/round across the three client schedulers.

The scale-axis claim behind the scheduler stack: vmap's transient working
set is O(K·M), chunked bounds it to O(chunk·M), and sharded splits that
over a client mesh to O(chunk·M / n_devices). This entry sweeps cohort
size K over all three (sharded on whatever devices the process sees —
force more with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
and reports per-round wall time plus the round's uplink savings so the
accounting can be eyeballed for scheduler-independence.
"""
from __future__ import annotations

from benchmarks.common import build_spec, emit


def run(rounds: int = 3, cohorts=(32, 128), chunk_size: int = 8) -> None:
    import jax

    from repro.fed import run_experiment

    n_dev = len(jax.devices())
    for K in cohorts:
        for sched in ("vmap", "chunked", "sharded"):
            flkw = dict(scheduler=sched, use_lbgm=True, delta_threshold=0.2,
                        lbg_variant="topk", lbg_kw={"k_frac": 0.1})
            if sched != "vmap":
                flkw["chunk_size"] = chunk_size
            if sched == "sharded":
                flkw.update(mesh=n_dev, lbg_variant="topk-sharded")
            spec = build_spec(num_clients=K, n_data=4 * K * 16,
                              name=f"cohort-{sched}-K{K}", **flkw)
            result = run_experiment(spec, rounds)
            emit(f"cohort_scaling/{sched}/K{K}", result.us_per_round,
                 f"savings={result.savings:.3f};n_dev={n_dev}")


if __name__ == "__main__":
    import benchmarks  # noqa: F401  (src/ path bootstrap)
    run()
