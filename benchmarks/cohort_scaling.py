"""Cohort-scaling benchmark: ms/round across the three client schedulers.

The scale-axis claim behind the scheduler stack: vmap's transient working
set is O(K·M), chunked bounds it to O(chunk·M), and sharded splits that
over a client mesh to O(chunk·M / n_devices). This entry sweeps cohort
size K over all three (sharded on whatever devices the process sees —
force more with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
and reports per-round wall time plus the round's uplink savings so the
accounting can be eyeballed for scheduler-independence.

The ``scalar_rounds`` section is the ISSUE-4 acceptance measurement: on
scalar-heavy rounds (delta=1, the paper's steady state — every
post-refresh round recycles) it times the SAME experiment under the
legacy dense-scatter aggregation (``fused_kernels=False``) and the sparse
scalar-round aggregation (default), chunked and sharded, and emits the
speedup. Warm-up rounds (jit compile + the round-0 LBG refresh) are
excluded; host prep is prefetched, so the number is steady-state device
time per round. tau/batch are kept small and the FCN widened so the
round is aggregation- rather than local-SGD-bound — the quantity this
section exists to measure.

The ``mesh_shapes`` section is the ISSUE-5 acceptance measurement: the
same scalar-heavy experiment across 2-D ``(clients, model)`` mesh shapes
— every factorization of the local device count — so BENCH_engine.json
records how the round time moves as the client axis trades devices with
the model axis.

The ``lm_model_sharding`` section is the ISSUE-8 acceptance measurement:
the ``"lm"`` component on one ``model > 1`` mesh shape under
``model_sharding="replicate"`` vs ``"auto"`` (client compute replicated
vs tensor-parallel along the model axis), reporting us/round and the
XLA-reported per-device temp bytes of the whole round.

The ``host_stream`` section is the ISSUE-10 acceptance measurement: the
same chunked experiment under the in-memory ``topk`` store vs the
out-of-core ``topk-host`` store (banks host-resident, streamed per chunk
on the background thread), reporting us/round plus the streamed-chunk
device bytes — the fixed per-round device bank footprint that holds
whatever K is. The ``tiered`` section runs the hierarchical
edge->region->global aggregation (``FLConfig.tiers``) and emits the
ledger's per-tier wire-byte attribution alongside us/round.

Every row emitted by this module carries ``mesh``/``mesh_shape``/
``fused_kernels``/``model_sharding``/``lbg_store``/``tiers`` metadata
(``common.spec_metadata``) so rows from different PRs are attributable
to the execution path that produced them.
"""
from __future__ import annotations

import time

from benchmarks.common import build_spec, emit, record_bench, spec_metadata


def _mesh_factorizations(n_dev: int):
    """(clients, model) shapes to sweep: every c*m == n_dev split."""
    return [(c, n_dev // c) for c in range(1, n_dev + 1) if n_dev % c == 0]


def run(rounds: int = 3, cohorts=(32, 128), chunk_size: int = 8,
        scalar_cohorts=(128,), scalar_rounds: int = 6,
        scalar_warmup: int = 2, scalar_d_model: int = 512,
        scalar_chunk: int = 16, scalar_k_frac: float = 0.01,
        mesh_cohorts=(32,), host_cohorts=(256,),
        tier_levels=(8, 2)) -> None:
    import jax

    from repro.fed import run_experiment

    n_dev = len(jax.devices())
    for K in cohorts:
        for sched in ("vmap", "chunked", "sharded"):
            flkw = dict(scheduler=sched, use_lbgm=True, delta_threshold=0.2,
                        lbg_variant="topk", lbg_kw={"k_frac": 0.1})
            if sched != "vmap":
                flkw["chunk_size"] = chunk_size
            if sched == "sharded":
                flkw.update(mesh=n_dev, lbg_variant="topk-sharded")
            spec = build_spec(num_clients=K, n_data=4 * K * 16,
                              name=f"cohort-{sched}-K{K}", **flkw)
            result = run_experiment(spec, rounds)
            emit(f"cohort_scaling/{sched}/K{K}", result.us_per_round,
                 f"savings={result.savings:.3f};n_dev={n_dev}",
                 K=K, n_dev=n_dev, **spec_metadata(spec))
    for K in scalar_cohorts:
        scalar_round_comparison(K, scalar_chunk, scalar_rounds,
                                scalar_warmup, scalar_d_model, n_dev,
                                k_frac=scalar_k_frac)
    for K in mesh_cohorts:
        mesh_shape_sweep(K, scalar_chunk, scalar_rounds, scalar_warmup,
                         scalar_d_model, n_dev, k_frac=scalar_k_frac)
    lm_model_sharding_comparison(scalar_rounds, scalar_warmup, n_dev)
    for K in host_cohorts:
        host_stream_comparison(K, chunk_size, rounds, warmup=2)
        tiered_aggregation(K, chunk_size, rounds, warmup=2,
                           levels=tier_levels)


def host_stream_comparison(K: int, chunk_size: int, rounds: int,
                           warmup: int) -> None:
    """In-memory ``topk`` vs out-of-core ``topk-host`` on the identical
    chunked experiment (histories are bit-for-bit equal — tier-1 tested
    — so the delta is pure execution cost). The topk-host row's derived
    field carries ``chunk_bytes``: the streamed bank chunk's device
    bytes, the whole per-round device bank footprint (x2 for the double
    buffer) at ANY cohort size."""
    import numpy as np

    from repro.fed.experiment import build_experiment

    for store in ("topk", "topk-host"):
        spec = build_spec(
            num_clients=K, n_data=4 * K * 8, tau=1, batch_size=8,
            name=f"host-{store}-K{K}", scheduler="chunked",
            chunk_size=chunk_size, use_lbgm=True, delta_threshold=0.2,
            lbg_variant=store, lbg_kw={"k_frac": 0.1})
        engine, _ = build_experiment(spec)
        rng = np.random.RandomState(spec.fl.seed + 1)
        src = engine.prefetcher(rng)
        try:
            for _ in range(warmup):
                engine.run_round(src)
            t0 = time.time()
            for _ in range(rounds):
                engine.run_round(src)
            us = (time.time() - t0) / rounds * 1e6
        finally:
            src.close()
        extra = {}
        derived = f"K={K};chunk={engine._chunk}"
        if store == "topk-host":
            extra["chunk_bytes"] = engine.host_chunk_device_bytes()
            derived += f";chunk_bytes={extra['chunk_bytes']}"
        emit(f"cohort_scaling/host_stream/{store}/K{K}", us, derived,
             K=K, **extra, **spec_metadata(spec))


def tiered_aggregation(K: int, chunk_size: int, rounds: int, warmup: int,
                       levels=(8, 2)) -> None:
    """Hierarchical edge->region->global fold (bit-for-bit the flat
    history) with the ledger's per-tier wire attribution in the row:
    edge links carry the real sparse payload bytes, each active
    edge/region ships one dense fp32 partial carry upstream."""
    import numpy as np

    from repro.fed.experiment import build_experiment

    levels = [int(n) for n in levels if int(n) >= 1]
    levels = [min(n, K) for n in levels]
    spec = build_spec(
        num_clients=K, n_data=4 * K * 8, tau=1, batch_size=8,
        name=f"tiered-{'x'.join(map(str, levels))}-K{K}",
        scheduler="chunked", chunk_size=chunk_size, use_lbgm=True,
        delta_threshold=0.2, lbg_variant="topk",
        lbg_kw={"k_frac": 0.1}, tiers=levels)
    engine, _ = build_experiment(spec)
    rng = np.random.RandomState(spec.fl.seed + 1)
    src = engine.prefetcher(rng)
    try:
        for _ in range(warmup):
            engine.run_round(src)
        t0 = time.time()
        for _ in range(rounds):
            engine.run_round(src)
        us = (time.time() - t0) / rounds * 1e6
    finally:
        src.close()
    tb = {f"tier_{k}_bytes": v
          for k, v in engine.ledger.tier_wire_bytes.items()}
    emit(f"cohort_scaling/tiered/{'x'.join(map(str, levels))}/K{K}", us,
         ";".join([f"K={K}"] + [f"{k}={v:.0f}" for k, v in tb.items()]),
         K=K, **tb, **spec_metadata(spec))


def mesh_shape_sweep(K: int, chunk_size: int, rounds: int, warmup: int,
                     d_model: int, n_dev: int,
                     k_frac: float = 0.01) -> None:
    """2-D mesh shapes, same experiment: how does us/round move as the
    ``n_dev`` local devices split between the client and model axes?

    Scalar-heavy rounds (delta=1) with the topk-sharded store, so the
    quantity under the knife is exactly what the 2-D mesh shards: the
    LBGM decision + sparse aggregation working set. ``(n_dev, 1)`` is the
    pre-2-D sharded baseline; shapes with model > 1 trade client
    parallelism for per-device bank memory (expect them slower on
    wall-clock when the local-SGD compute — replicated along model —
    dominates, as on CPU hosts: the model axis buys HBM, not flops).
    """
    for c, m in _mesh_factorizations(n_dev):
        chunk = max(chunk_size, c)  # block must split over the client axis
        spec = build_spec(
            num_clients=K, n_data=4 * K * 8, tau=1, batch_size=8,
            model_kw={"d_model": d_model},
            name=f"mesh-{c}x{m}-K{K}", scheduler="sharded",
            mesh=[c, m], use_lbgm=True, delta_threshold=1.0,
            chunk_size=chunk, lbg_variant="topk-sharded",
            lbg_kw={"k_frac": k_frac})
        us = _time_scalar_rounds(spec, rounds, warmup)
        emit(f"cohort_scaling/mesh_shapes/{c}x{m}/K{K}", us,
             f"delta=1.0 d_model={d_model} k_frac={k_frac} tau=1 "
             f"n_dev={n_dev} mesh=({c},{m})",
             K=K, d_model=d_model, k_frac=k_frac, n_dev=n_dev,
             **spec_metadata(spec))


def lm_model_sharding_comparison(rounds: int, warmup: int, n_dev: int,
                                 K: int = 8, chunk: int = 4) -> None:
    """replicate-vs-auto ``model_sharding`` on the ``"lm"`` component: the
    same 2-D mesh either replicates each client's local-SGD
    forward/backward along the model axis (``"replicate"`` — only banks /
    decision / aggregation shard, the pre-tensor-parallel behaviour) or
    runs it model-sharded (``"auto"``). Emits us/round plus the
    XLA-reported whole-round temp bytes per device, so BENCH_engine.json
    records what the TP path buys in working-set memory and costs in
    wall-clock (on CPU hosts expect auto slower: the model axis buys
    memory, not flops).
    """
    import numpy as np

    from repro.fed import (ComponentSpec, EvalPolicy, ExperimentSpec,
                           FLConfig)
    from repro.fed.experiment import build_experiment

    shapes = [s for s in _mesh_factorizations(n_dev) if s[1] > 1]
    if not shapes:
        return  # single device: no model axis to compare over
    c, m = next((s for s in shapes if s[0] > 1), shapes[0])
    vocab = 512
    for ms in ("replicate", "auto"):
        spec = ExperimentSpec(
            name=f"lm-msharding-{ms}-{c}x{m}",
            model=ComponentSpec("lm", {"arch": "qwen3-1.7b",
                                       "reduced": True,
                                       "vocab_size": vocab}),
            data=ComponentSpec("markov", {"n": 16 * K, "n_eval": 0,
                                          "seq_len": 16, "vocab": vocab}),
            partition=ComponentSpec("iid", {}),
            fl=FLConfig(num_clients=K, tau=1, lr=0.02, batch_size=4,
                        use_lbgm=True, delta_threshold=1.0,
                        seed=0, scheduler="sharded",
                        chunk_size=max(chunk, c), mesh=[c, m],
                        lbg_variant="topk-sharded",
                        lbg_kw={"k_frac": 0.01}, model_sharding=ms),
            eval=EvalPolicy(every=0, final=False))
        engine, _ = build_experiment(spec)
        rng = np.random.RandomState(spec.fl.seed + 1)
        src = engine.prefetcher(rng)
        try:
            for _ in range(warmup):
                engine.run_round(src)
            t0 = time.time()
            for _ in range(rounds):
                engine.run_round(src)
            elapsed = time.time() - t0
        finally:
            src.close()
        us = elapsed / max(rounds, 1) * 1e6
        tmp = _round_temp_bytes(engine)
        per_dev = tmp // n_dev if tmp is not None else None
        emit(f"cohort_scaling/lm_model_sharding/{ms}/{c}x{m}", us,
             f"temp_bytes_per_dev={per_dev} vocab={vocab} tau=1 "
             f"n_dev={n_dev} mesh=({c},{m})",
             K=K, n_dev=n_dev, temp_bytes_per_dev=per_dev,
             **spec_metadata(spec))


def _round_temp_bytes(engine):
    """XLA whole-round temp allocation (memory_analysis; None when the
    backend does not report it) — lowered on the live arrays so banks and
    params keep their mesh placements."""
    import numpy as np
    import jax.numpy as jnp

    batch = engine._sample_batches(np.random.RandomState(0))
    mask = jnp.ones(engine.cfg.num_clients, jnp.float32)
    lowered = engine._round.lower(engine.params, engine.lbg,
                                  engine.residual, batch, mask)
    stats = lowered.compile().memory_analysis()
    if stats is None or not hasattr(stats, "temp_size_in_bytes"):
        return None
    return int(stats.temp_size_in_bytes)


def _time_scalar_rounds(spec, rounds: int, warmup: int) -> float:
    """Steady-state us/round: warm-up (compile + LBG refresh) excluded,
    host prep prefetched so only device round time is on the clock."""
    import numpy as np

    from repro.fed.experiment import build_experiment

    engine, _ = build_experiment(spec)
    rng = np.random.RandomState(spec.fl.seed + 1)
    src = engine.prefetcher(rng)
    try:
        for _ in range(warmup):
            engine.run_round(src)
        t0 = time.time()
        for _ in range(rounds):
            m = engine.run_round(src)
        elapsed = time.time() - t0
    finally:
        src.close()
    assert m["frac_scalar"] == 1.0, "scalar-heavy config must recycle"
    return elapsed / max(rounds, 1) * 1e6


def scalar_round_comparison(K: int, chunk_size: int, rounds: int,
                            warmup: int, d_model: int, n_dev: int,
                            k_frac: float = 0.01) -> None:
    """dense-scatter (the pre-PR path, ``fused_kernels=False``: per-client
    dense g_tilde scatter, O(M) sequential accumulation, full padded-block
    decision) vs the default sparse scalar-round aggregation, on
    all-recycle rounds. ``k_frac=0.01`` is the App-C.1 LBG-compression
    density of the large-model regime the ROADMAP targets — the setting
    where "work proportional to what the round transmits" matters most."""
    for sched in ("chunked", "sharded"):
        flkw = dict(scheduler=sched, use_lbgm=True, delta_threshold=1.0,
                    chunk_size=chunk_size, lbg_variant="topk",
                    lbg_kw={"k_frac": k_frac})
        if sched == "sharded":
            flkw.update(mesh=n_dev, lbg_variant="topk-sharded")
        us = {}
        for label, fused in (("dense", False), ("sparse", None)):
            spec = build_spec(
                num_clients=K, n_data=4 * K * 8, tau=1, batch_size=8,
                model_kw={"d_model": d_model}, fused_kernels=fused,
                name=f"scalar-{sched}-K{K}-{label}", **flkw)
            us[label] = _time_scalar_rounds(spec, rounds, warmup)
            emit(f"cohort_scaling/scalar_rounds/{sched}/K{K}/{label}",
                 us[label],
                 f"delta=1.0 d_model={d_model} k_frac={k_frac} tau=1 "
                 f"n_dev={n_dev} fused_kernels={fused}",
                 K=K, path=label, d_model=d_model,
                 k_frac=k_frac, n_dev=n_dev, **spec_metadata(spec))
        # the ratio row reports the ratio itself (not a time): CSV + JSON
        # are written directly so the us_per_round field isn't abused
        ratio = us["dense"] / max(us["sparse"], 1e-9)
        name = f"cohort_scaling/scalar_rounds/{sched}/K{K}/speedup"
        derived = (f"dense_us={us['dense']:.0f} "
                   f"sparse_us={us['sparse']:.0f} "
                   f"speedup={ratio:.2f}x (acceptance: >=1.3x; row value "
                   "is the dense/sparse ratio, not a time)")
        print(f"{name},{ratio:.2f},{derived}")
        meta = {"derived": derived, "K": K, "speedup": ratio,
                **spec_metadata(spec)}
        meta["fused_kernels"] = "false-vs-default"  # the row IS the compare
        record_bench(name, ratio, meta)


if __name__ == "__main__":
    import benchmarks  # noqa: F401  (src/ path bootstrap)
    run()
