"""Paper Fig. 7: LBGM as plug-and-play on top of top-K and ATOMO —
additional savings over the base compressor."""
from __future__ import annotations

from benchmarks.common import build_spec, emit


def run(rounds=30, scheduler="vmap"):
    """Three stacks: top-K+EF (error feedback churns the sent support, so
    consecutive compressed gradients barely overlap — LBGM degrades
    *gracefully* to the base compressor, mirroring the paper's own 2/24
    inconsistent-overlap cases, Figs. 52-53), top-K without EF (strong
    recycling), and ATOMO."""
    from repro.fed import run_experiment

    results = {}
    settings = [
        ("topk_ef", "topk", {"k_frac": 0.1}, True, 0.75),
        ("topk", "topk", {"k_frac": 0.1}, False, 0.5),
        ("atomo", "atomo", {"rank": 2}, False, 0.5),
    ]
    for tag, comp, kw, use_ef, delta in settings:
        res_b = run_experiment(
            build_spec(name=f"fig7_{tag}", use_lbgm=False, compressor=comp,
                       compressor_kw=kw, error_feedback=use_ef, noniid=True,
                       scheduler=scheduler), rounds)
        res_l = run_experiment(
            build_spec(name=f"fig7_{tag}+lbgm", use_lbgm=True,
                       delta_threshold=delta, compressor=comp,
                       compressor_kw=kw, error_feedback=use_ef, noniid=True,
                       scheduler=scheduler), rounds)
        acc_b = res_b.final_eval["test_acc"]
        acc_l = res_l.final_eval["test_acc"]
        extra = 1 - res_l.total_uplink / res_b.total_uplink
        emit(f"fig7_{tag}", res_b.us_per_round,
             f"acc={acc_b:.3f} uplink={res_b.total_uplink:.3g}")
        emit(f"fig7_{tag}+lbgm", res_l.us_per_round,
             f"acc={acc_l:.3f} uplink={res_l.total_uplink:.3g} "
             f"extra_savings={extra:.1%}")
        results[tag] = {"acc_base": acc_b, "acc_lbgm": acc_l,
                        "extra_savings": extra}
    return results


if __name__ == "__main__":
    print(run())
