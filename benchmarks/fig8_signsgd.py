"""Paper Fig. 8: LBGM on top of SignSGD in distributed (iid) training —
bits-transferred reduction."""
from __future__ import annotations

from benchmarks.common import build_fl, emit, timed_rounds


def run(rounds=30, scheduler="vmap"):
    base, ev = build_fl(use_lbgm=False, compressor="signsgd", noniid=False,
                        tau=1, scheduler=scheduler)
    us_b = timed_rounds(base, rounds)
    acc_b = ev(base.params)["test_acc"]

    # sign-compressed gradients agree on a fraction p of coordinates =>
    # cos ~ (2p-1); threshold tuned accordingly (paper App. C.2)
    fl, ev = build_fl(use_lbgm=True, delta_threshold=0.7,
                      compressor="signsgd", noniid=False, tau=1,
                      scheduler=scheduler)
    us_l = timed_rounds(fl, rounds)
    acc_l = ev(fl.params)["test_acc"]
    extra = 1 - fl.total_uplink / base.total_uplink
    emit("fig8_signsgd", us_b,
         f"acc={acc_b:.3f} uplink_float_equiv={base.total_uplink:.3g}")
    emit("fig8_signsgd+lbgm", us_l,
         f"acc={acc_l:.3f} uplink_float_equiv={fl.total_uplink:.3g} "
         f"extra_savings={extra:.1%}")
    return {"acc_base": acc_b, "acc_lbgm": acc_l, "extra_savings": extra}


if __name__ == "__main__":
    print(run())
