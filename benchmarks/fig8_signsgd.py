"""Paper Fig. 8: LBGM on top of SignSGD in distributed (iid) training —
bits-transferred reduction."""
from __future__ import annotations

from benchmarks.common import build_spec, emit


def run(rounds=30, scheduler="vmap"):
    from repro.fed import run_experiment

    res_b = run_experiment(
        build_spec(name="fig8_signsgd", use_lbgm=False, compressor="signsgd",
                   noniid=False, tau=1, scheduler=scheduler), rounds)
    # sign-compressed gradients agree on a fraction p of coordinates =>
    # cos ~ (2p-1); threshold tuned accordingly (paper App. C.2)
    res_l = run_experiment(
        build_spec(name="fig8_signsgd+lbgm", use_lbgm=True,
                   delta_threshold=0.7, compressor="signsgd", noniid=False,
                   tau=1, scheduler=scheduler), rounds)
    acc_b = res_b.final_eval["test_acc"]
    acc_l = res_l.final_eval["test_acc"]
    extra = 1 - res_l.total_uplink / res_b.total_uplink
    emit("fig8_signsgd", res_b.us_per_round,
         f"acc={acc_b:.3f} uplink_float_equiv={res_b.total_uplink:.3g}")
    emit("fig8_signsgd+lbgm", res_l.us_per_round,
         f"acc={acc_l:.3f} uplink_float_equiv={res_l.total_uplink:.3g} "
         f"extra_savings={extra:.1%}")
    return {"acc_base": acc_b, "acc_lbgm": acc_l, "extra_savings": extra}


if __name__ == "__main__":
    print(run())
