"""Paper Fig. 6: effect of delta_threshold — larger thresholds buy more
communication savings at some accuracy cost (takeaway 5)."""
from __future__ import annotations

from benchmarks.common import build_fl, emit, timed_rounds


def run(rounds=40, deltas=(0.01, 0.05, 0.2, 0.4)):
    results = {}
    base, ev = build_fl(use_lbgm=False, noniid=True)
    timed_rounds(base, rounds)
    van_uplink = base.total_uplink
    for d in deltas:
        fl, ev = build_fl(use_lbgm=True, delta_threshold=d, noniid=True)
        us = timed_rounds(fl, rounds)
        acc = ev(fl.params)["test_acc"]
        sav = 1 - fl.total_uplink / van_uplink
        emit(f"fig6_delta_{d}", us,
             f"acc={acc:.3f} savings={sav:.1%} "
             f"frac_scalar={fl.history[-1]['frac_scalar']:.2f}")
        results[d] = {"acc": acc, "savings": sav}
    return results


if __name__ == "__main__":
    print(run())
