"""Paper Fig. 6: effect of delta_threshold — larger thresholds buy more
communication savings at some accuracy cost (takeaway 5). The threshold
grid runs through the declarative ``sweep()`` driver."""
from __future__ import annotations

from benchmarks.common import build_spec, emit


def run(rounds=40, deltas=(0.01, 0.05, 0.2, 0.4)):
    from repro.fed import run_experiment, sweep

    res_van = run_experiment(
        build_spec(name="fig6_vanilla", use_lbgm=False, noniid=True), rounds)
    van_uplink = res_van.total_uplink

    base_spec = build_spec(name="fig6", use_lbgm=True, noniid=True)
    results = {}
    for point, res in sweep(base_spec,
                            {"fl.delta_threshold": list(deltas)}, rounds):
        d = point["fl.delta_threshold"]
        acc = res.final_eval["test_acc"]
        sav = 1 - res.total_uplink / van_uplink
        emit(f"fig6_delta_{d}", res.us_per_round,
             f"acc={acc:.3f} savings={sav:.1%} "
             f"frac_scalar={res.records[-1].frac_scalar:.2f}")
        results[d] = {"acc": acc, "savings": sav}
    return results


if __name__ == "__main__":
    print(run())
