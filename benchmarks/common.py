"""Shared benchmark harness utilities.

Every figure benchmark describes its run as an
:class:`~repro.fed.experiment.ExperimentSpec` (via :func:`build_spec`) and
executes it with ``repro.fed.run_experiment`` / ``sweep`` — no hand-wired
``FLEngine`` construction.

:func:`emit` prints the human-readable ``name,us,derived`` CSV row AND
appends a structured entry to the ``BENCH_engine.json`` trajectory file
(name, us_per_round, metadata, git rev, timestamp), so perf numbers from
different revisions are diffable instead of living only in CI logs. Set
``BENCH_ENGINE_PATH`` to redirect the file (CI uploads it as an artifact).
"""
from __future__ import annotations

import functools
import json
import os
import subprocess
import tempfile
import time
import warnings
from typing import Any, Dict, Optional

#: trajectory file every benchmark appends to (one JSON array)
BENCH_PATH_ENV = "BENCH_ENGINE_PATH"
BENCH_PATH_DEFAULT = "BENCH_engine.json"


def build_spec(num_clients=20, tau=2, lr=0.05, batch_size=16, seed=0,
               noniid=True, n_data=2000, n_eval=500, name="benchmark",
               classes_per_client=3,
               model_kw: Optional[Dict[str, Any]] = None, **flkw):
    """Paper-style FL experiment spec: FCN classifier on synthetic mixture
    data, non-iid label-skew split by default.

    Extra **flkw go straight into FLConfig — e.g. scheduler="chunked",
    chunk_size=32 for the memory-bounded large-cohort path;
    fused_kernels=False pins the legacy dense aggregation path.
    ``model_kw`` passes arch overrides to the model component (e.g.
    {"d_model": 512} to scale the FCN width). ``classes_per_client``
    tunes the label-skew severity (1 = each client holds a single
    class's shard — the regime where losing a client cohort can lose
    whole classes, used by the async straggler benchmark).
    """
    from repro.fed import ComponentSpec, EvalPolicy, ExperimentSpec, FLConfig

    partition = (ComponentSpec("label_skew",
                               {"classes_per_client": classes_per_client,
                                "seed": seed})
                 if noniid else ComponentSpec("iid", {"seed": seed}))
    return ExperimentSpec(
        name=name,
        model=ComponentSpec("fcn", dict(model_kw or {})),
        data=ComponentSpec("mixture",
                           {"n": n_data, "n_eval": n_eval, "seed": seed}),
        partition=partition,
        fl=FLConfig(num_clients=num_clients, tau=tau, lr=lr,
                    batch_size=batch_size, seed=seed, **flkw),
        eval=EvalPolicy(every=0, final=True),
    )


def spec_metadata(spec) -> Dict[str, Any]:
    """The attribution keys every BENCH_engine.json row should carry:
    which execution path produced the number. ``mesh`` is the raw
    JSON-able FLConfig knob (None / int / [clients, model]);
    ``mesh_shape`` the resolved (clients, model) pair (None-spec resolves
    to every local device on the client axis, matching ``make_fl_mesh``);
    ``fused_kernels`` the raw tri-state knob; ``kernel_variant`` the
    active fused sparse-decision kernel (the ``REPRO_LBGM_TWO_PASS_TOPK``
    Mosaic-safety env knob); ``codec``/``codec_kw`` the wire codec. Rows
    from different PRs stay diffable because the path is in the row, not
    in the CI log."""
    import jax

    from repro.kernels.ops import _default_two_pass
    fl = spec.fl
    shape = fl.mesh_shape
    if shape is None and fl.scheduler == "sharded":
        shape = (len(jax.devices()), 1)
    return {
        "mesh": fl.mesh,
        "mesh_shape": list(shape) if shape is not None else None,
        "fused_kernels": fl.fused_kernels,
        "kernel_variant": ("two_pass_topk" if _default_two_pass()
                          else "one_pass_topk"),
        "scheduler": fl.scheduler,
        "model_sharding": fl.model_sharding,
        "codec": fl.codec,
        "codec_kw": fl.codec_kw,
        "latency": fl.latency,
        "latency_kw": fl.latency_kw,
        "lbg_store": fl.resolved_lbg_variant if fl.use_lbgm else None,
        "tiers": fl.tiers,
    }


@functools.lru_cache(maxsize=1)
def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def bench_path() -> str:
    return os.environ.get(BENCH_PATH_ENV, BENCH_PATH_DEFAULT)


def record_bench(name: str, us_per_round: float,
                 metadata: Optional[Dict[str, Any]] = None) -> None:
    """Append one entry to the BENCH_engine.json trajectory array.

    The read-modify-write is crash-safe: the new array is written to a
    sibling temp file and moved into place with ``os.replace`` (atomic on
    POSIX), so a benchmark killed mid-write — or two processes racing —
    can no longer leave a truncated file that a later run would silently
    reset. An existing file that fails to parse is backed up next to the
    trajectory (``<path>.corrupt-<n>``) instead of being discarded: a
    perf trajectory spanning many revisions is exactly the artifact you
    don't want a one-off glitch to zero out.
    """
    path = bench_path()
    entries = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                entries = json.load(f)
            if not isinstance(entries, list):
                raise ValueError(
                    f"expected a JSON array, got {type(entries).__name__}")
        except (OSError, ValueError) as e:
            entries = []
            backup = _backup_corrupt(path)
            warnings.warn(
                f"unreadable bench trajectory {path!r} ({e}); "
                + (f"backed up to {backup!r} and " if backup else "")
                + "starting a fresh trajectory",
                RuntimeWarning, stacklevel=2)
    entries.append({
        "name": name,
        "us_per_round": float(us_per_round),
        "metadata": dict(metadata or {}),
        "git_rev": _git_rev(),
        "timestamp": time.time(),
    })
    directory = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".bench-", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(entries, f, indent=1)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _backup_corrupt(path: str) -> Optional[str]:
    """Move an unparsable trajectory aside; returns the backup path
    (numbered so repeated failures don't clobber each other), or None if
    even the rename failed."""
    for n in range(1000):
        backup = f"{path}.corrupt-{n}"
        if not os.path.exists(backup):
            try:
                os.replace(path, backup)
                return backup
            except OSError:
                return None
    return None


def emit(name: str, us_per_call: float, derived: str,
         **metadata: Any) -> None:
    """CSV row to stdout + structured entry to BENCH_engine.json."""
    print(f"{name},{us_per_call:.0f},{derived}")
    record_bench(name, us_per_call, {"derived": derived, **metadata})
