"""Shared benchmark harness utilities.

Every figure benchmark describes its run as an
:class:`~repro.fed.experiment.ExperimentSpec` (via :func:`build_spec`) and
executes it with ``repro.fed.run_experiment`` / ``sweep`` — no hand-wired
``FLEngine`` construction.
"""
from __future__ import annotations


def build_spec(num_clients=20, tau=2, lr=0.05, batch_size=16, seed=0,
               noniid=True, n_data=2000, n_eval=500, name="benchmark",
               **flkw):
    """Paper-style FL experiment spec: FCN classifier on synthetic mixture
    data, non-iid label-skew split by default.

    Extra **flkw go straight into FLConfig — e.g. scheduler="chunked",
    chunk_size=32 for the memory-bounded large-cohort path.
    """
    from repro.fed import ComponentSpec, EvalPolicy, ExperimentSpec, FLConfig

    partition = (ComponentSpec("label_skew",
                               {"classes_per_client": 3, "seed": seed})
                 if noniid else ComponentSpec("iid", {"seed": seed}))
    return ExperimentSpec(
        name=name,
        model=ComponentSpec("fcn"),
        data=ComponentSpec("mixture",
                           {"n": n_data, "n_eval": n_eval, "seed": seed}),
        partition=partition,
        fl=FLConfig(num_clients=num_clients, tau=tau, lr=lr,
                    batch_size=batch_size, seed=seed, **flkw),
        eval=EvalPolicy(every=0, final=True),
    )


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.0f},{derived}")
