"""Shared benchmark harness utilities."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np


def build_fl(num_clients=20, tau=2, lr=0.05, batch_size=16, seed=0,
             noniid=True, n_data=2000, **flkw):
    """Paper-style FL engine: FCN classifier on synthetic mixture data.

    Extra **flkw go straight into FLConfig — e.g. scheduler="chunked",
    chunk_size=32 for the memory-bounded large-cohort path.
    """
    from repro.configs import get_config
    from repro.data.synthetic import mixture_classification
    from repro.fed import FLConfig, FLEngine, partition_iid, \
        partition_label_skew
    from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn

    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(seed), cfg)
    n_test = 500
    x_all, y_all = mixture_classification(n_data + n_test, 10, seed=seed)
    x, y = x_all[:n_data], y_all[:n_data]
    xe, ye = x_all[n_data:], y_all[n_data:]        # held-out, same mixture
    parts = (partition_label_skew(y, num_clients, 3, seed=seed) if noniid
             else partition_iid(len(y), num_clients, seed=seed))
    data = [{"x": x[p], "y": y[p]} for p in parts]
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    fl = FLEngine(loss_fn, params, data,
                  FLConfig(num_clients=num_clients, tau=tau, lr=lr,
                           batch_size=batch_size, seed=seed, **flkw))

    def evaluate(params):
        _, m = loss_fn(params, {"x": jax.numpy.asarray(xe),
                                "y": jax.numpy.asarray(ye)})
        return {"test_acc": float(m["acc"])}

    return fl, evaluate


def timed_rounds(fl, rounds: int, seed=1):
    rng = np.random.RandomState(seed)
    t0 = time.time()
    for _ in range(rounds):
        fl.run_round(rng)
    return (time.time() - t0) / rounds * 1e6  # us per round


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.0f},{derived}")
