# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows. Figures covered: 1 (PCA), 5 (standalone), 6 (threshold),
# 7 (plug-and-play), 8 (SignSGD distributed), + kernel micro-bench.
#
# Run from the repo root as a module (the package __init__ bootstraps the
# src/ path, same convention as pytest.ini's ``pythonpath = src``):
#
#     python -m benchmarks.run --only fig5
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig5,fig6,fig7,fig8,kernels,"
                         "cohort,robustness,wire_bytes,async")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--toy", action="store_true",
                    help="tiny problem sizes (CI smoke): small kernel "
                         "vectors, small cohorts, narrow model — exercises "
                         "every code path incl. the BENCH_engine.json "
                         "trajectory, makes no perf claims")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    def on(name):
        return want is None or name in want

    print("name,us_per_call,derived")
    if on("fig1"):
        from benchmarks import fig1_pca
        fig1_pca.run(epochs=25)
    if on("fig5"):
        from benchmarks import fig5_standalone
        fig5_standalone.run(rounds=args.rounds)
    if on("fig6"):
        from benchmarks import fig6_threshold
        fig6_threshold.run(rounds=args.rounds)
    if on("fig7"):
        from benchmarks import fig7_plugplay
        fig7_plugplay.run(rounds=args.rounds)
    if on("fig8"):
        from benchmarks import fig8_signsgd
        fig8_signsgd.run(rounds=args.rounds)
    if on("kernels"):
        from benchmarks import kernel_bench
        if args.toy:
            kernel_bench.run(n=1 << 16, batch=4, iters=2)
        else:
            kernel_bench.run()
    if on("cohort"):
        from benchmarks import cohort_scaling
        if args.toy:
            cohort_scaling.run(rounds=2, cohorts=(8,), chunk_size=4,
                               scalar_cohorts=(8,), scalar_rounds=2,
                               scalar_warmup=2, scalar_d_model=64,
                               mesh_cohorts=(8,), host_cohorts=(16,),
                               tier_levels=(4, 2))
        else:
            cohort_scaling.run(rounds=min(args.rounds, 5))
    if on("robustness"):
        from benchmarks import robustness
        if args.toy:
            robustness.run(rounds=3, num_clients=8, n_data=320,
                           fracs=(0.25,),
                           attacks=(("sign_flip", {"scale": 4.0}),),
                           headline_frac=0.25)
        else:
            robustness.run(rounds=args.rounds)
    if on("wire_bytes"):
        from benchmarks import wire_bytes
        if args.toy:
            wire_bytes.run(rounds=3, num_clients=8, n_data=320)
        else:
            wire_bytes.run(rounds=args.rounds)
    if on("async"):
        from benchmarks import async_heterogeneity
        if args.toy:
            async_heterogeneity.run(rounds=4, num_clients=8, n_data=320,
                                    fracs=(0.25,), delays=(2,),
                                    aggs=("mean",), headline_frac=0.25)
        else:
            async_heterogeneity.run(rounds=max(args.rounds, 40))


if __name__ == '__main__':
    main()
