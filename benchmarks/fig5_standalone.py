"""Paper Fig. 5: LBGM standalone vs vanilla FL — accuracy vs floating-point
parameters shared (non-iid, delta = 0.2)."""
from __future__ import annotations

from benchmarks.common import build_spec, emit


def run(rounds=40, delta=0.2, scheduler="vmap", chunk_size=16):
    """scheduler/chunk_size select the engine's client-scheduling path:
    "chunked" bounds transient memory to O(chunk_size·M) for large K."""
    from repro.fed import run_experiment

    res_v = run_experiment(
        build_spec(name="fig5_vanilla", use_lbgm=False, noniid=True,
                   scheduler=scheduler, chunk_size=chunk_size), rounds)
    res_l = run_experiment(
        build_spec(name="fig5_lbgm", use_lbgm=True, delta_threshold=delta,
                   noniid=True, scheduler=scheduler,
                   chunk_size=chunk_size), rounds)
    acc_v = res_v.final_eval["test_acc"]
    acc_l = res_l.final_eval["test_acc"]
    savings = 1 - res_l.total_uplink / res_v.total_uplink

    emit("fig5_vanilla_fl", res_v.us_per_round,
         f"acc={acc_v:.3f} uplink_floats={res_v.total_uplink:.3g}")
    emit("fig5_lbgm", res_l.us_per_round,
         f"acc={acc_l:.3f} uplink_floats={res_l.total_uplink:.3g} "
         f"savings={savings:.1%} acc_drop={acc_v - acc_l:+.3f}")
    return {"acc_vanilla": acc_v, "acc_lbgm": acc_l, "savings": savings}


if __name__ == "__main__":
    print(run())
