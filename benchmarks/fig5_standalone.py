"""Paper Fig. 5: LBGM standalone vs vanilla FL — accuracy vs floating-point
parameters shared (non-iid, delta = 0.2)."""
from __future__ import annotations

from benchmarks.common import build_fl, emit, timed_rounds


def run(rounds=40, delta=0.2, scheduler="vmap", chunk_size=16):
    """scheduler/chunk_size select the engine's client-scheduling path:
    "chunked" bounds transient memory to O(chunk_size·M) for large K."""
    fl_v, ev = build_fl(use_lbgm=False, noniid=True, scheduler=scheduler,
                        chunk_size=chunk_size)
    us_v = timed_rounds(fl_v, rounds)
    acc_v = ev(fl_v.params)["test_acc"]

    fl_l, ev = build_fl(use_lbgm=True, delta_threshold=delta, noniid=True,
                        scheduler=scheduler, chunk_size=chunk_size)
    us_l = timed_rounds(fl_l, rounds)
    acc_l = ev(fl_l.params)["test_acc"]
    savings = 1 - fl_l.total_uplink / fl_v.total_uplink

    emit("fig5_vanilla_fl", us_v,
         f"acc={acc_v:.3f} uplink_floats={fl_v.total_uplink:.3g}")
    emit("fig5_lbgm", us_l,
         f"acc={acc_l:.3f} uplink_floats={fl_l.total_uplink:.3g} "
         f"savings={savings:.1%} acc_drop={acc_v - acc_l:+.3f}")
    return {"acc_vanilla": acc_v, "acc_lbgm": acc_l, "savings": savings}


if __name__ == "__main__":
    print(run())
