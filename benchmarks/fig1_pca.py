"""Paper Fig. 1: N95/N99-PCA of the gradient space across training epochs
(H1: the gradient subspace is low-rank — N-PCA << #epochs)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.analysis.pca import GradientSpaceTracker
from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.models.smallnets import apply_cnn, classifier_loss, init_cnn


def run(epochs=30, seed=0):
    cfg = get_config("paper-cnn")
    params, _ = init_cnn(jax.random.PRNGKey(seed), cfg)
    x, y = mixture_classification(1024, 10, seed=seed)
    x, y = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, xb, yb: classifier_loss(apply_cnn, p, cfg, xb, yb)[0]
    grad_fn = jax.jit(jax.grad(loss_fn))
    lr = 0.05
    tracker = GradientSpaceTracker()
    rng = np.random.RandomState(seed)
    t0 = time.time()
    for ep in range(epochs):
        acc = None
        epoch_grad = jax.tree.map(jnp.zeros_like, params)
        for b in range(8):                       # 8 minibatches / epoch
            idx = rng.randint(0, x.shape[0], 128)
            g = grad_fn(params, x[idx], y[idx])
            params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
            epoch_grad = jax.tree.map(jnp.add, epoch_grad, g)
        tracker.add(epoch_grad)
    us = (time.time() - t0) / epochs * 1e6
    s = tracker.summary()
    emit("fig1_pca_n99", us,
         f"n99={s['n99_final']}/epochs={epochs} "
         f"n95={s['n95_final']} lowrank={s['n99_final'] < epochs // 2}")
    return s


if __name__ == "__main__":
    print(run())
