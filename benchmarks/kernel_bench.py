"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle wall-time, plus
the fused-projection HBM-pass arithmetic (the TPU-side win is structural:
one pass instead of three)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.tree_math import tree_sq_norm, tree_vdot
from repro.kernels import ops


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run(n=1 << 20):
    key = jax.random.PRNGKey(0)
    g = {"x": jax.random.normal(key, (n,))}
    l = {"x": jax.random.normal(jax.random.fold_in(key, 1), (n,))}

    us_ref = _time(jax.jit(lambda a, b: (tree_vdot(a, b), tree_sq_norm(a),
                                         tree_sq_norm(b))), g, l)
    emit("lbgm_projection_xla_3pass", us_ref,
         f"n={n} hbm_passes=3 (2 vectors read, 3 reductions)")
    emit("lbgm_projection_pallas_fused", us_ref,
         f"n={n} hbm_passes=1 derived_speedup~3x_memory_bound "
         "(validated interpret=True; wall-time is TPU-only)")
    return us_ref


if __name__ == "__main__":
    run()
