"""Kernel micro-benchmarks for the fused LBGM decision hot path.

Times the XLA 3-pass oracle AND the fused Pallas kernels — each row is a
real wall-time measurement of the thing it names (an earlier revision
reported the XLA timing under the Pallas row; see BENCH_engine.json for
the honest trajectory). On CPU the Pallas rows run the interpreter, so
they are expected to be SLOWER than XLA — the fused win is structural
(one HBM pass instead of three) and lands on TPU, where the same calls
compile to Mosaic; the XLA row is the portable fallback the engine uses
when ``FLConfig.fused_kernels`` resolves off.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.tree_math import tree_sq_norm, tree_vdot
from repro.kernels import ops


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run(n: int = 1 << 20, batch: int = 8, iters: int = 5):
    key = jax.random.PRNGKey(0)
    g = {"x": jax.random.normal(key, (n,))}
    l = {"x": jax.random.normal(jax.random.fold_in(key, 1), (n,))}
    backend = jax.default_backend()
    interp = ops._default_interpret()
    mode = "interpret" if interp else "mosaic"

    us_ref = _time(jax.jit(lambda a, b: (tree_vdot(a, b), tree_sq_norm(a),
                                         tree_sq_norm(b))), g, l,
                   iters=iters)
    emit("lbgm_projection_xla_3pass", us_ref,
         f"n={n} hbm_passes=3 (2 vectors read, 3 reductions)",
         n=n, backend=backend)

    us_pallas = _time(jax.jit(lambda a, b: ops.lbgm_projection(a, b)), g, l,
                      iters=iters)
    emit("lbgm_projection_pallas_fused", us_pallas,
         f"n={n} hbm_passes=1 mode={mode} "
         f"xla_3pass_us={us_ref:.0f} (fused win is TPU-structural; the "
         "interpreter row only validates the kernel)",
         n=n, backend=backend, mode=mode, xla_3pass_us=us_ref)

    # batched kernel: the schedulers' client axis on grid dim 0
    gb = jax.random.normal(key, (batch, n // batch))
    lb = jax.random.normal(jax.random.fold_in(key, 2), (batch, n // batch))
    us_vmap_ref = _time(
        jax.jit(jax.vmap(lambda a, b: (jnp.vdot(a, b), jnp.vdot(a, a),
                                       jnp.vdot(b, b)))), gb, lb,
        iters=iters)
    emit("lbgm_projection_xla_3pass_batched", us_vmap_ref,
         f"B={batch} n={n // batch} hbm_passes=3",
         n=n // batch, batch=batch, backend=backend)
    from repro.kernels.lbgm_projection import lbgm_projection_batched_pallas
    us_batched = _time(
        jax.jit(lambda a, b: lbgm_projection_batched_pallas(a, b)), gb, lb,
        iters=iters)
    emit("lbgm_projection_pallas_fused_batched", us_batched,
         f"B={batch} n={n // batch} hbm_passes=1 mode={mode} "
         f"xla_us={us_vmap_ref:.0f}",
         n=n // batch, batch=batch, backend=backend, mode=mode,
         xla_3pass_us=us_vmap_ref)
    return us_ref, us_pallas


if __name__ == "__main__":
    import benchmarks  # noqa: F401  (src/ path bootstrap)
    run()
