"""Benchmark package — runnable as ``python -m benchmarks.run`` from the
repo root.

The repo's import convention is pytest.ini's ``pythonpath = src``; outside
pytest nothing puts ``src/`` on ``sys.path``, so this package bootstraps it
once, centrally, instead of per-script ``sys.path.insert`` hacks.
"""
from __future__ import annotations

import importlib.util
import os
import sys

if importlib.util.find_spec("repro") is None:
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
