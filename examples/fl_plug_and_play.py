"""LBGM as a plug-and-play layer on top of top-K sparsification with error
feedback (paper P3), compared against top-K alone.

    PYTHONPATH=src python examples/fl_plug_and_play.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLEngine, partition_label_skew
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn


def build(use_lbgm: bool, scheduler: str = "chunked"):
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)
    x, y = mixture_classification(2000, 10)
    parts = partition_label_skew(y, 20, 3)
    data = [{"x": x[p], "y": y[p]} for p in parts]
    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    # chunked scheduler: lax.scan over blocks of 10 clients bounds the
    # round's working set to O(10·M) instead of O(20·M) — same numbers
    return FLEngine(loss_fn, params, data,
                    FLConfig(num_clients=20, tau=2, lr=0.05,
                             use_lbgm=use_lbgm, delta_threshold=0.2,
                             compressor="topk",
                             compressor_kw={"k_frac": 0.1},
                             error_feedback=True,
                             scheduler=scheduler, chunk_size=10))


def main():
    rounds = 40
    base = build(use_lbgm=False)
    base.run(rounds)
    stacked = build(use_lbgm=True)
    stacked.run(rounds)
    print(f"top-K alone : loss {base.history[-1]['loss']:.4f}, "
          f"uplink {base.total_uplink:.3g} floats")
    print(f"top-K + LBGM: loss {stacked.history[-1]['loss']:.4f}, "
          f"uplink {stacked.total_uplink:.3g} floats")
    print(f"LBGM extra savings on top of top-K: "
          f"{1 - stacked.total_uplink / base.total_uplink:.1%}")


if __name__ == "__main__":
    main()
