"""LBGM as a plug-and-play layer on top of top-K sparsification with error
feedback (paper P3), compared against top-K alone — two runs of the same
``ExperimentSpec`` differing only in ``fl.use_lbgm``.

    PYTHONPATH=src python examples/fl_plug_and_play.py
"""
from repro.fed import (ComponentSpec, EvalPolicy, ExperimentSpec, FLConfig,
                       run_experiment)


def make_spec(use_lbgm: bool, scheduler: str = "chunked") -> ExperimentSpec:
    # chunked scheduler: lax.scan over blocks of 10 clients bounds the
    # round's working set to O(10·M) instead of O(20·M) — same numbers
    return ExperimentSpec(
        name="topk+lbgm" if use_lbgm else "topk",
        model=ComponentSpec("fcn"),
        data=ComponentSpec("mixture", {"n": 2000, "n_eval": 0}),
        partition=ComponentSpec("label_skew", {"classes_per_client": 3}),
        fl=FLConfig(num_clients=20, tau=2, lr=0.05,
                    use_lbgm=use_lbgm, delta_threshold=0.2,
                    compressor="topk", compressor_kw={"k_frac": 0.1},
                    error_feedback=True,
                    scheduler=scheduler, chunk_size=10),
        rounds=40,
        # this comparison is about uplink, not accuracy: skip eval entirely
        eval=EvalPolicy(every=0, final=False),
    )


def main():
    base = run_experiment(make_spec(use_lbgm=False))
    stacked = run_experiment(make_spec(use_lbgm=True))
    print(f"top-K alone : loss {base.records[-1].loss:.4f}, "
          f"uplink {base.total_uplink:.3g} floats")
    print(f"top-K + LBGM: loss {stacked.records[-1].loss:.4f}, "
          f"uplink {stacked.total_uplink:.3g} floats")
    print(f"LBGM extra savings on top of top-K: "
          f"{1 - stacked.total_uplink / base.total_uplink:.1%}")


if __name__ == "__main__":
    main()
