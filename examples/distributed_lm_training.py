"""End-to-end driver: distributed LBGM training of a transformer LM on a
synthetic markov corpus (paper §P4: LBGM generalizes to distributed
training; here clients = data-parallel ranks, tau = 1).

Defaults are CPU-sized; pass --full for the ~100M-parameter configuration
(qwen3 family at d_model=768, 12 layers) x a few hundred steps — the exact
run recorded in EXPERIMENTS.md.

    PYTHONPATH=src python examples/distributed_lm_training.py [--full]
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params x 300 steps (hours on CPU)")
    ap.add_argument("--steps", type=int, default=None)
    args, _ = ap.parse_known_args()

    if args.full:
        argv = ["--arch", "qwen3-1.7b", "--reduced",
                "--d-model", "768", "--layers", "12", "--vocab", "8192",
                "--steps", str(args.steps or 300), "--seq", "512",
                "--batch", "4", "--clients", "4", "--lr", "0.02",
                "--out", "experiments/train_100m"]
    else:
        argv = ["--arch", "qwen3-1.7b", "--reduced",
                "--d-model", "256", "--layers", "4", "--vocab", "2048",
                "--steps", str(args.steps or 60), "--seq", "256",
                "--batch", "4", "--clients", "4", "--lr", "0.02",
                "--out", "experiments/train_demo"]
    hist = train_main(argv)
    first, last = hist[0], hist[-1]
    print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} over "
          f"{len(hist)} steps with LBGM gradient recycling")


if __name__ == "__main__":
    main()
