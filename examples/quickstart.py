"""Quickstart: declarative LBGM federated learning in ~30 lines.

An experiment is one serializable object — an ``ExperimentSpec`` naming the
model / dataset / partitioner by registry key plus the FL knobs — and one
call: ``run_experiment(spec)``. The same spec round-trips through JSON
(``spec.to_json()`` / ``ExperimentSpec.from_json``) and drives the CLI:

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python -m repro.fed.run --set fl.delta_threshold=0.4
"""
from repro.fed import (ComponentSpec, EvalPolicy, ExperimentSpec, FLConfig,
                       run_experiment)


def main():
    spec = ExperimentSpec(
        name="quickstart",
        # FCN classifier on the synthetic 28x28 mixture dataset; non-iid
        # split where each of 20 clients sees only 3 of 10 classes
        model=ComponentSpec("fcn"),
        data=ComponentSpec("mixture", {"n": 2000, "num_classes": 10}),
        partition=ComponentSpec("label_skew", {"classes_per_client": 3}),
        fl=FLConfig(num_clients=20, tau=2, lr=0.05, use_lbgm=True,
                    delta_threshold=0.2),
        rounds=40,
        eval=EvalPolicy(every=10, final=True, verbose=True),
    )
    assert spec == ExperimentSpec.from_json(spec.to_json())  # lossless

    result = run_experiment(spec)

    last = result.records[-1]
    print(f"\nfinal loss {last.loss:.4f} | test acc "
          f"{result.final_eval['test_acc']:.3f} | uplink savings vs "
          f"vanilla FL: {result.savings:.1%} | scalar rounds: "
          f"{last.frac_scalar:.0%}")


if __name__ == "__main__":
    main()
