"""Quickstart: LBGM federated learning in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.data.synthetic import mixture_classification
from repro.fed import FLConfig, FLSystem, partition_label_skew
from repro.models.smallnets import apply_fcn, classifier_loss, init_fcn


def main():
    cfg = get_config("paper-fcn")
    params, _ = init_fcn(jax.random.PRNGKey(0), cfg)

    # non-iid federated split: each of 20 clients sees only 3 of 10 classes
    x, y = mixture_classification(2000, num_classes=10)
    parts = partition_label_skew(y, num_clients=20, classes_per_client=3)
    data = [{"x": x[p], "y": y[p]} for p in parts]

    loss_fn = lambda p, b: classifier_loss(apply_fcn, p, cfg, b["x"], b["y"])
    fl = FLSystem(loss_fn, params, data,
                  FLConfig(num_clients=20, tau=2, lr=0.05,
                           use_lbgm=True, delta_threshold=0.2))
    fl.run(rounds=40, verbose=True, eval_every=10)

    m = fl.history[-1]
    print(f"\nfinal loss {m['loss']:.4f} | uplink savings vs vanilla FL: "
          f"{m['savings']:.1%} | scalar rounds: {m['frac_scalar']:.0%}")


if __name__ == "__main__":
    main()
