"""2-D (clients x model) mesh execution of a large arch — end to end.

``FLConfig.mesh=[2, 4]`` runs each round's client chunks data-parallel
over 2 client devices while the LBGM look-back banks, the Algorithm-1
accept/recycle decision, and the sparse aggregation carry shard their
block rows over 4 model devices: per-device bank bytes drop to
O(K·k_frac·M / 8). The spec file is the whole experiment —
``examples/specs/yi34b_mesh2x4.json`` names a *reduced* yi-34b (CPU-sized;
drop ``model.kw.reduced`` on real accelerators) over the ``"lm"`` model
component and the markov-LM dataset.

Mesh-spec compatibility rule: ``fl.mesh`` is ``None`` (every local device
on the client axis), an int ``n`` (exactly ``[n, 1]`` — the pre-2-D
spelling, bit-for-bit identical rounds), or ``[clients, model]``. A
``[c, 1]`` mesh reproduces the 1-D sharded scheduler bit-for-bit and
``[1, 1]`` reproduces the chunked scheduler bit-for-bit, so specs can be
promoted gradually.

Run (8 forced host devices on CPU; on a real pod skip XLA_FLAGS):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python examples/mesh2d_lm.py

or through the CLI on the same spec:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.fed.run --spec examples/specs/yi34b_mesh2x4.json

``fl.model_sharding="auto"`` goes one step further: the client
forward/backward itself runs tensor-parallel along the model axis
(the "lm" component hands the engine its arch's named-axis tree, and
the sharded scheduler resolves it into per-leaf PartitionSpecs).
``examples/specs/yi34b_tp2x4.json`` runs the FULL 60-layer yi-34b
depth — width-reduced so a CPU container can hold it — on the same
2x4 mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.fed.run --spec examples/specs/yi34b_tp2x4.json
"""
import os

if "XLA_FLAGS" not in os.environ:  # default to an 8-device host mesh
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from repro.fed import ExperimentSpec, run_experiment  # noqa: E402

SPEC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "specs",
                    "yi34b_mesh2x4.json")


def main():
    spec = ExperimentSpec.load(SPEC)
    assert spec == ExperimentSpec.from_json(spec.to_json())  # lossless
    print(f"[{spec.name}] mesh={spec.fl.mesh} -> shape "
          f"{spec.fl.mesh_shape} (clients x model)")
    result = run_experiment(spec)
    last = result.records[-1]
    print(f"{result.rounds} rounds | loss {last.loss:.4f} | "
          f"test loss {result.final_eval.get('test_loss', float('nan')):.4f}"
          f" | uplink savings {result.savings:.1%} | "
          f"scalar rounds {last.frac_scalar:.0%}")


if __name__ == "__main__":
    main()
